"""CTCluster: consistent-hash placement (determinism + bounded
relocation), kill/stall/poison failover through the health monitor,
bit-identity of failed-over serving against fresh single engines, and
the threaded stress tier (8 submitters, mid-run host kill, zero hung or
silently dropped futures).
"""

import threading
import time

import numpy as np
import pytest

from proptest import cases, integers, seeds

from repro.core.engine import (CTEngine, EngineSaturated, ExecSpec,
                               clear_compile_cache)
from repro.core.executor import build_plan
from repro.core.levels import CombinationScheme, grid_shape
from repro.runtime.cluster import (CTCluster, HashRing, HostFailed,
                                   PROBE_TENANT)
from repro.runtime.elastic import rebalance_cluster
from repro.runtime.fault_tolerance import HostHealthConfig

pytestmark = pytest.mark.cluster


@pytest.fixture(autouse=True)
def _fresh_caches():
    clear_compile_cache()
    yield


def _grids(scheme, seed):
    rng = np.random.default_rng(seed)
    return {ell: rng.standard_normal(grid_shape(ell))
            for ell, _ in scheme.grids}


def _wait_for(cond, timeout=30.0, interval=0.02):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(interval)
    return cond()


SCHEME = CombinationScheme(3, 3)


def _cluster_with_tenants(n_tenants=6, **kw):
    kw.setdefault("seed", 11)
    cl = CTCluster(4, **kw)
    for i in range(n_tenants):
        cl.register(f"t{i}", SCHEME, _grids(SCHEME, i))
    return cl


# ---------------------------------------------------------------------------
# Placement: determinism + bounded relocation (satellite property test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "seed,n", cases(lambda r: (seeds(r), integers(r, 3, 8)), n=10))
def test_ring_placement_deterministic_and_bounded_relocation(seed, n):
    """Same (hosts, vnodes, seed) -> identical owner lists; removing one
    of N hosts relocates only the keys it owned (~T/N), never reshuffles
    the rest; adding it back restores the original map exactly."""
    hosts = [f"host{i}" for i in range(n)]
    keys = [f"tenant-{k}" for k in range(200)]
    r1 = HashRing(hosts, seed=seed)
    r2 = HashRing(hosts, seed=seed)
    assert all(r1.owners(k, 2) == r2.owners(k, 2) for k in keys)

    shrunk = HashRing(hosts[:-1], seed=seed)
    gone = hosts[-1]
    moved = sum(1 for k in keys
                if r1.owners(k)[0] != gone
                and r1.owners(k) != shrunk.owners(k))
    assert moved == 0          # survivors' primaries never move
    relocated = sum(1 for k in keys if r1.owners(k)[0] == gone)
    # vnodes keep per-host load near T/N: allow 2x slack for hash noise
    assert relocated <= 2 * len(keys) // n

    grown = HashRing(hosts, seed=seed)   # "restart" after re-adding
    assert all(grown.owners(k, 2) == r1.owners(k, 2) for k in keys)


@pytest.mark.parametrize(
    "seed,n", cases(lambda r: (seeds(r), integers(r, 3, 8)), n=10))
def test_ring_remove_then_readd_restores_exact_placement(seed, n):
    """The ``restart_host`` placement contract at the ring level: a host
    that leaves and rejoins under the same seeded vnodes gets back
    EXACTLY its pre-failure assignment, at every replication factor —
    which is why a restarted host finds its own tenants in its own
    durable store instead of pulling state across the network."""
    hosts = [f"host{i}" for i in range(n)]
    keys = [f"tenant-{k}" for k in range(150)]
    before = HashRing(hosts, seed=seed)
    readded = HashRing(list(hosts), seed=seed)   # leave + rejoin
    for r in (1, 2, 3):
        assert all(readded.owners(k, r) == before.owners(k, r)
                   for k in keys)


@pytest.mark.parametrize(
    "seed,n", cases(lambda r: (seeds(r), integers(r, 3, 8)), n=10))
def test_ring_relocation_bounded_in_both_directions(seed, n):
    """Relocation is bounded by the victim's OWN tenants in both
    directions: removal only reassigns keys whose owner walk crossed
    the victim, and re-adding only reassigns keys that RETURN to the
    victim — every other key's owner list is bit-for-bit unchanged."""
    hosts = [f"host{i}" for i in range(n)]
    keys = [f"tenant-{k}" for k in range(150)]
    victim = hosts[-1]
    full = HashRing(hosts, seed=seed)
    shrunk = HashRing(hosts[:-1], seed=seed)
    # removal: untouched owner walks stay identical
    for k in keys:
        if victim not in full.owners(k, 2):
            assert shrunk.owners(k, 2) == full.owners(k, 2)
    # re-add: the ONLY keys that move are the ones the victim reclaims,
    # and each lands exactly on its pre-removal owner list
    regrown = HashRing(hosts, seed=seed)
    for k in keys:
        if regrown.owners(k, 2) != shrunk.owners(k, 2):
            assert victim in regrown.owners(k, 2)
            assert regrown.owners(k, 2) == full.owners(k, 2)


def test_cluster_restart_recomputes_identical_placement():
    """A rebuilt cluster (same host count, vnodes, seed) places every
    tenant on the same owners — placement is a pure function of the
    ring, not of registration order or process state."""
    a = _cluster_with_tenants(8, replication=2)
    b = CTCluster(4, replication=2, seed=11)
    for i in reversed(range(8)):         # opposite registration order
        b.register(f"t{i}", SCHEME, _grids(SCHEME, i))
    assert {n: a.owners_of(n) for n in a.names()} \
        == {n: b.owners_of(n) for n in b.names()}


def test_add_host_rebalances_bounded_and_stays_correct():
    """Joining host N+1 relocates ~tenants/(N+1) tenants (moved owners
    adopt plan + surplus, no re-ingest) and every answer is unchanged."""
    cl = _cluster_with_tenants(8)
    pts = np.random.default_rng(1).random((16, 3))
    want = {n: cl.query(n, pts) for n in cl.names()}
    before = {n: cl.owners_of(n) for n in cl.names()}
    cl.add_host()
    moved = [n for n in cl.names() if cl.owners_of(n) != before[n]]
    assert len(moved) <= 2 * 8 // 5 + 1
    out = rebalance_cluster(cl)          # idempotent: already reconciled
    assert set(out.values()) <= {"kept"}
    for n in cl.names():
        np.testing.assert_array_equal(cl.query(n, pts), want[n])


# ---------------------------------------------------------------------------
# Failover: kill one of 4 hosts (acceptance)
# ---------------------------------------------------------------------------

def _fresh_oracle(cl, name, pts):
    """A FRESH single engine serving ``name``'s post-fault scheme from
    the cluster's retained grids, on the same fine grid (full_levels) —
    the bit-identity oracle for failed-over serving."""
    rec = cl._records[name]
    eng = CTEngine()
    plan = build_plan(rec.scheme, cl.plan(name).full_levels)
    eng.register(name, rec.scheme, rec.grids, plan=plan)
    return eng.query(name, pts)


def test_kill_one_of_four_hosts_every_tenant_stays_queryable():
    """The headline failover path: kill a host with live tenants and
    in-flight work.  Every tenant remains queryable with answers
    bit-identical to a fresh single engine serving the same post-fault
    scheme; queries in flight on the victim are transparently retried;
    an unreplicated in-flight ingest resolves with the named
    ``HostFailed`` and its component grid is recombined away."""
    cl = _cluster_with_tenants(6, replication=1)
    pts = np.random.default_rng(2).random((32, 3))
    want = {n: cl.query(n, pts) for n in cl.names()}

    victim = cl.owners_of("t0")[0]
    victim_tenants = [n for n in cl.names()
                      if cl.owners_of(n)[0] == victim]
    # in-flight on the victim at kill time: one query (idempotent ->
    # retried) and one PARTIAL ingest (unreplicated -> lost -> the
    # carried component grid is dropped and the scheme recombined)
    q_inflight = cl.submit_query("t0", pts)
    lost_level = next(ell for ell, c in cl.scheme("t0").grids if c != 0)
    i_inflight = cl.submit_ingest(
        "t0", {lost_level: np.full(grid_shape(lost_level), 2.0)})

    cl.injector.kill(victim)
    failed = cl.check_health()           # manual monitor pass
    assert failed == [victim]
    assert victim not in cl.live_hosts()

    # the in-flight query retried transparently and answers the POST-
    # fault state (the lost grid left the combination, so the serving
    # function legitimately changed — but the future resolved, unasked)
    assert q_inflight.retargeted == 1
    np.testing.assert_array_equal(q_inflight.result(30),
                                  cl.query("t0", pts))
    # the unreplicated in-flight ingest fails NAMED, never hangs
    with pytest.raises(HostFailed, match="t0.*no replica") as ei:
        i_inflight.result(30)
    assert ei.value.host_id == victim

    # its component grid was recombined away, Harding-style
    assert lost_level in cl._records["t0"].dropped
    assert lost_level not in {ell for ell, _ in cl.scheme("t0").grids}

    st = cl.stats()
    assert st["failovers"] and st["failovers"][0]["recovery_ms"] > 0
    assert st["failovers"][0]["outcomes"]["t0"] == "recombined"
    for n in cl.names():
        assert victim not in cl.owners_of(n)
        np.testing.assert_array_equal(cl.query(n, pts),
                                      _fresh_oracle(cl, n, pts))
    # tenants the victim did not own are bitwise untouched
    for n in set(cl.names()) - set(victim_tenants):
        np.testing.assert_array_equal(cl.query(n, pts), want[n])


def test_replicated_tenant_survives_primary_kill_without_data_loss():
    """With R=2 the replica absorbs everything: an ingest in flight on
    the dying primary re-points at the replica's acknowledgement (no
    ``HostFailed``), and the new data serves after failover."""
    cl = _cluster_with_tenants(6, replication=2)
    cl.start()
    try:
        pts = np.random.default_rng(3).random((16, 3))
        base = cl.query("t1", pts)
        victim = cl.owners_of("t1")[0]
        f_new = cl.submit_ingest("t1", _grids(SCHEME, 99))
        cl.injector.kill(victim)
        surplus = f_new.result(60)       # replica ack resolves it
        assert np.all(np.isfinite(np.asarray(surplus)))
        assert _wait_for(lambda: victim not in cl.live_hosts(), 30)
        after = cl.query("t1", pts)
        assert not np.array_equal(after, base)      # new data serves
        np.testing.assert_array_equal(after, _fresh_oracle(cl, "t1", pts))
        assert cl.stats()["host_failed"] == 0
    finally:
        cl.stop()


def test_stall_detection_via_heartbeat_and_probe_deadline():
    """A stalled host never admits death — only the monitor's heartbeat
    age + missed probe deadlines catch it (strike accounting), after
    which its tenants fail over exactly like a kill."""
    cl = _cluster_with_tenants(
        4, health=HostHealthConfig(heartbeat_timeout_s=0.3,
                                   probe_deadline_s=0.3, max_strikes=2),
        monitor_interval_s=0.1)
    cl.start()
    try:
        pts = np.random.default_rng(4).random((16, 3))
        want = {n: cl.query(n, pts) for n in cl.names()}
        victim = cl.owners_of("t0")[0]
        cl.injector.stall(victim)
        assert _wait_for(lambda: victim not in cl.live_hosts(), 30)
        reason = cl.stats()["failovers"][0]["reason"]
        assert "strike" in reason or "heartbeat" in reason \
            or "probe" in reason
        for n in cl.names():
            np.testing.assert_array_equal(cl.query(n, pts), want[n])
    finally:
        cl.stop()


def test_poisoned_ingest_fails_only_its_future_host_stays_up():
    """The NaN-poison seam is a DATA fault, not a host fault: the
    poisoned ingest's future resolves with ``FloatingPointError``, the
    host keeps serving, siblings and the tenant's retained state are
    untouched, and no failover fires."""
    cl = _cluster_with_tenants(4)
    pts = np.random.default_rng(5).random((16, 3))
    want = {n: cl.query(n, pts) for n in cl.names()}
    cl.injector.poison_next_ingest("t2")
    bad = cl.submit_ingest("t2", _grids(SCHEME, 42))
    ok = cl.submit_query("t3", pts)
    with pytest.raises(FloatingPointError, match="non-finite"):
        bad.result(60)
    np.testing.assert_array_equal(ok.result(60), want["t3"])
    assert len(cl.live_hosts()) == 4
    assert cl.stats()["failovers"] == []
    np.testing.assert_array_equal(cl.query("t2", pts), want["t2"])
    # the poisoned payload never committed into the retained grids
    clean = cl.submit_ingest("t2", _grids(SCHEME, 42))
    assert np.all(np.isfinite(np.asarray(clean.result(60))))


def test_unregister_and_saturated_routing_errors_are_named():
    cl = _cluster_with_tenants(2)
    with pytest.raises(KeyError, match="no tenant 'nope'"):
        cl.submit_query("nope", np.zeros((1, 3)))
    with pytest.raises(ValueError, match="reserved"):
        cl.register(PROBE_TENANT, SCHEME, _grids(SCHEME, 0))
    cl.unregister("t0")
    assert "t0" not in cl.names()
    with pytest.raises(KeyError, match="t0"):
        cl.query("t0", np.zeros((1, 3)))


def test_unregister_tears_engines_down_outside_the_cluster_lock():
    """Pinned regression (repro.analysis block-under-lock finding):
    engine unregister frees device buffers and discards the durable
    store — disk IO that must NOT run under the cluster lock, or a
    slow teardown stalls serving traffic for every other tenant.  The
    routing record disappears under the lock; the engine teardown
    happens after it is released."""
    cl = _cluster_with_tenants(2)
    owners = list(cl._records["t0"].owners)
    lock_owned_during_teardown = []
    for hid in owners:
        eng = cl._hosts[hid].engine
        orig = eng.unregister

        def spy(name, _orig=orig):
            lock_owned_during_teardown.append(cl._lock._is_owned())
            return _orig(name)

        eng.unregister = spy
    cl.unregister("t0")
    assert len(lock_owned_during_teardown) == len(owners)
    assert not any(lock_owned_during_teardown)
    assert "t0" not in cl.names()
    for hid in owners:
        assert "t0" not in cl._hosts[hid].engine
    # the survivor keeps serving
    r = cl.query("t1", np.random.default_rng(3).random((4, 3)))
    assert np.asarray(r).shape == (4,)


def test_add_host_warms_probe_outside_the_cluster_lock():
    """Pinned regression (repro.analysis dispatch-under-lock finding,
    caught live by the REPRO_LOCKDEP=1 cluster tier): ``add_host``
    registered + warmed the probe tenant — an XLA compile plus a
    device dispatch — while holding the cluster lock, stalling every
    tenant's serving traffic for the duration of the compile.  The
    lock now only reserves the host id and publishes the ready host;
    the probe warmup runs in between, lock-free."""
    from repro.runtime.cluster import CTCluster
    cl = _cluster_with_tenants(2)
    lock_owned_during_warmup = []
    orig = CTCluster._add_probe_tenant

    def spy(self, engine):
        lock_owned_during_warmup.append(self._lock._is_owned())
        return orig(self, engine)

    CTCluster._add_probe_tenant = spy
    try:
        hid = cl.add_host()
    finally:
        CTCluster._add_probe_tenant = orig
    assert lock_owned_during_warmup == [False]
    assert hid in cl._hosts
    assert not cl._joining
    # the new host is live and placement stays correct
    pts = np.random.default_rng(5).random((4, 3))
    for n in cl.names():
        assert np.asarray(cl.query(n, pts)).shape == (4,)
    with pytest.raises(ValueError):
        cl.add_host(hid)


def test_surrogate_rides_the_cluster_unchanged():
    """``CTSurrogate(cluster=)``: the one-tenant convenience API routes
    through placement/health/failover with identical answers."""
    from repro.launch.serve import CTSurrogate
    cl = CTCluster(3, seed=5)
    g = _grids(SCHEME, 7)
    sur = CTSurrogate(SCHEME, g, cluster=cl)
    eng = CTEngine()
    eng.register("oracle", SCHEME, g)
    pts = np.random.default_rng(6).random((24, 3))
    np.testing.assert_array_equal(sur.query(pts), eng.query("oracle", pts))
    g2 = _grids(SCHEME, 8)
    sur.update(g2)
    eng.update("oracle", g2)
    np.testing.assert_array_equal(sur.query(pts), eng.query("oracle", pts))
    with pytest.raises(ValueError, match="not both"):
        CTSurrogate(SCHEME, g, engine=eng, cluster=cl)


@pytest.mark.multidevice
def test_meshed_hosts_over_disjoint_device_slices():
    """Hosts over disjoint slices of the 8 fake devices: each tenant
    runs slab-sharded on its owner's slice, answers match an unmeshed
    oracle engine."""
    import jax
    if len(jax.devices()) < 8:
        pytest.skip("needs the 8 fake host devices")
    cl = CTCluster.over_device_slices(4, seed=11)
    g = _grids(SCHEME, 1)
    cl.register("t", SCHEME, g)
    eng = CTEngine()
    eng.register("t", SCHEME, g)
    pts = np.random.default_rng(7).random((16, 3))
    np.testing.assert_array_equal(cl.query("t", pts), eng.query("t", pts))
    victim = cl.owners_of("t")[0]
    cl.injector.kill(victim)
    cl.check_health()
    np.testing.assert_array_equal(cl.query("t", pts), eng.query("t", pts))


# ---------------------------------------------------------------------------
# Threaded stress: 8 submitters, mid-run kill, zero hung/dropped futures
# ---------------------------------------------------------------------------

def test_stress_eight_submitters_mid_run_kill_no_dropped_futures():
    """Acceptance stress tier: 8 threads hammer queries + ingests while
    one of 4 hosts is killed mid-run.  EVERY future must resolve — to a
    value or to a named error (``HostFailed`` for unreplicated in-flight
    ingests) — within the drain timeout; zero hangs, zero silent drops,
    and every tenant stays queryable afterwards."""
    cl = _cluster_with_tenants(6, replication=1)
    cl.start()
    futures, flock = [], threading.Lock()
    stop_evt = threading.Event()
    pts = np.random.default_rng(8).random((8, 3))
    errors = []

    def submitter(tid):
        rng = np.random.default_rng(100 + tid)
        k = 0
        while not stop_evt.is_set():
            name = f"t{int(rng.integers(6))}"
            try:
                if tid < 2 and k % 3 == 0:
                    ell = SCHEME.grids[int(rng.integers(
                        len(SCHEME.grids)))][0]
                    f = cl.submit_ingest(name, {
                        ell: rng.standard_normal(grid_shape(ell))})
                else:
                    f = cl.submit_query(name, pts)
                with flock:
                    futures.append(f)
            except (KeyError, HostFailed,
                    EngineSaturated) as e:         # named routing errors
                errors.append(e)
            k += 1
            time.sleep(0.002)

    threads = [threading.Thread(target=submitter, args=(i,))
               for i in range(8)]
    try:
        for t in threads:
            t.start()
        time.sleep(0.6)
        victim = cl.owners_of("t0")[0]
        cl.injector.kill(victim)               # mid-run host loss
        assert _wait_for(lambda: victim not in cl.live_hosts(), 30)
        time.sleep(0.6)
    finally:
        stop_evt.set()
        for t in threads:
            t.join(timeout=30)
        assert not any(t.is_alive() for t in threads)

    hung = dropped = 0
    for f in futures:
        if not f.wait(60):
            hung += 1
            continue
        err = f.error()
        if err is not None and not isinstance(
                err, (HostFailed, FloatingPointError, KeyError,
                      EngineSaturated)):
            dropped += 1                      # unnamed error = a drop
    assert hung == 0 and dropped == 0
    assert len(futures) > 50                  # the stress actually ran
    cl.stop()
    for n in cl.names():
        assert victim not in cl.owners_of(n)
        out = cl.query(n, pts)
        assert np.all(np.isfinite(out))
        np.testing.assert_array_equal(out, _fresh_oracle(cl, n, pts))


# ---------------------------------------------------------------------------
# ClusterFuture: retarget-vs-resolve atomicity (bugfix regression)
# ---------------------------------------------------------------------------

class _FakeInner:
    """Stand-in engine future with a controllable ``done_at`` stamp."""

    def __init__(self, done_at=None):
        self.done_at = done_at

    def done(self):
        return False

    def wait(self, timeout=None):
        return False


def test_cluster_future_retarget_vs_resolve_atomic():
    """``done_at``/``retargeted``/``_inner`` are written from the
    monitor thread (failover retarget) and a resolving waiter thread;
    the per-future lock must serialize them: a future retargeted while
    resolving can neither double-resolve, nor lose its ``done_at``
    stamp, nor end up done-but-pointing-at-the-new-inner."""
    from repro.runtime.cluster import ClusterFuture

    for trial in range(200):
        fut = ClusterFuture(None, "ingest", "t", "h0",
                            _FakeInner(done_at=123.0))
        barrier = threading.Barrier(3)
        new_inner = _FakeInner(done_at=None)

        def resolve():
            barrier.wait()
            fut._finalize_locked(value="v")

        def retarget():
            barrier.wait()
            fut._retarget_locked("h1", new_inner)

        threads = [threading.Thread(target=resolve),
                   threading.Thread(target=retarget)]
        for t in threads:
            t.start()
        barrier.wait()
        for t in threads:
            t.join(timeout=30)

        assert fut._done and fut._value == "v" and fut._error is None
        assert fut.done_at is not None          # the stamp never lost
        if fut.retargeted == 0:
            # resolve won: retarget-after-done was a clean no-op
            assert fut._host_id == "h0" and fut._inner.done_at == 123.0
            assert fut.done_at == 123.0
        else:
            # retarget won: resolution stamped against the NEW inner
            assert fut.retargeted == 1 and fut._host_id == "h1"

        # a second resolution is always a no-op (no double-resolve)
        fut._finalize_locked(error=RuntimeError("late"))
        assert fut._value == "v" and fut._error is None
