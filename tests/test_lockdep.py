"""Self-tests for the runtime lock-order sanitizer.

The sanitizer must (a) deterministically flag a synthetic A->B/B->A
ordering cycle without needing the deadlock interleaving to happen,
(b) flag rank regressions against the registry, and (c) — the hard
requirement — change NOTHING about lock semantics: a 4-thread engine
workload run under ``lockdep.enable()`` must produce bit-identical
results to the uninstrumented run.
"""

import threading

import numpy as np
import pytest

from repro.analysis import lockdep
from repro.core.engine import CTEngine, clear_compile_cache
from repro.core.levels import CombinationScheme, grid_shape


@pytest.fixture()
def dep():
    """Instrumentation forced on, graph cleared, restored after."""
    lockdep.enable()
    lockdep.reset()
    yield lockdep
    lockdep.reset()
    lockdep.restore_default()


def _violation_rules(dep):
    return [v["rule"] for v in dep.violations()]


# ---------------------------------------------------------------------------
# detector
# ---------------------------------------------------------------------------

def test_disabled_returns_plain_locks():
    lockdep.disable()       # forced off, even under REPRO_LOCKDEP=1
    try:
        assert type(lockdep.make_lock("x")) is type(threading.Lock())
    finally:
        lockdep.restore_default()


def test_synthetic_cycle_flagged_deterministically(dep):
    a = dep.make_lock("alpha")
    b = dep.make_lock("beta")
    # thread 1's order...
    with a:
        with b:
            pass
    # ...and thread 2's inverted order, replayed sequentially: the
    # graph-based detector must flag the POTENTIAL deadlock without
    # the actual interleaving.
    with b:
        with a:
            pass
    cycles = dep.report()["cycles"]
    assert len(cycles) == 1
    assert set(cycles[0]["path"]) == {"alpha", "beta"}
    assert "lock-cycle" in _violation_rules(dep)


def test_no_cycle_for_consistent_order(dep):
    a = dep.make_lock("alpha")
    b = dep.make_lock("beta")
    for _ in range(3):
        with a:
            with b:
                pass
    rep = dep.report()
    assert rep["cycles"] == []
    assert [(e["from"], e["to"]) for e in rep["edges"]] == \
        [("alpha", "beta")]
    assert rep["edges"][0]["count"] == 3


def test_rank_regression_flagged(dep):
    engine = dep.make_rlock("engine")
    cluster = dep.make_rlock("cluster")
    with engine:
        with cluster:      # cluster(10) under engine(20): wrong way
            pass
    kinds = [v.get("kind") for v in dep.violations()]
    assert "rank-regression" in kinds


def test_rank_increasing_order_clean(dep):
    cluster = dep.make_rlock("cluster")
    engine = dep.make_rlock("engine")
    with cluster:
        with engine:
            pass
    assert dep.violations() == []


def test_same_class_two_instances_flagged(dep):
    e1 = dep.make_rlock("engine")
    e2 = dep.make_rlock("engine")
    with e1:
        with e2:
            pass
    kinds = [v.get("kind") for v in dep.violations()]
    assert "same-class-nesting" in kinds


def test_reentrant_reacquire_not_flagged(dep):
    e = dep.make_rlock("engine")
    with e:
        with e:
            pass
    assert dep.violations() == []


def test_note_dispatch_under_lock_flagged(dep):
    e = dep.make_rlock("engine")
    with e:
        dep.note_dispatch("test-site")
    v = dep.report()["dispatch_under_lock"]
    assert len(v) == 1
    assert v[0]["held"] == ["engine"]
    assert v[0]["site"] == "test-site"


def test_note_dispatch_without_lock_clean(dep):
    dep.note_dispatch("test-site")
    assert dep.violations() == []


def test_allowed_dispatch_section_suppresses(dep):
    e = dep.make_rlock("cluster")
    with e:
        with dep.allowed_dispatch("control-plane barrier"):
            dep.note_dispatch("test-site")
    assert dep.violations() == []


# ---------------------------------------------------------------------------
# wrapper semantics: Condition protocol + reentrancy bookkeeping
# ---------------------------------------------------------------------------

def test_condition_wait_notify_roundtrip(dep):
    lock = dep.make_rlock("engine")
    cond = threading.Condition(lock)
    state = {"ready": False, "seen": False}

    def waiter():
        with cond:
            while not state["ready"]:
                cond.wait(5)
            state["seen"] = True

    t = threading.Thread(target=waiter)
    t.start()
    with cond:
        state["ready"] = True
        cond.notify_all()
    t.join(5)
    assert state["seen"]
    assert dep.violations() == []


def test_condition_wait_releases_reentrant_levels(dep):
    # wait() from TWO levels deep must fully release (another thread
    # can acquire) and restore both levels afterwards.
    lock = dep.make_rlock("engine")
    cond = threading.Condition(lock)
    acquired_elsewhere = threading.Event()

    def other():
        with lock:
            acquired_elsewhere.set()
            with cond:
                cond.notify_all()

    with lock:          # level 1
        with cond:      # level 2 (same RLock through the Condition)
            t = threading.Thread(target=other)
            t.start()
            while not acquired_elsewhere.is_set():
                cond.wait(5)
        assert lock._is_owned()
    t.join(5)
    assert dep.violations() == []


def test_wrapper_stack_balanced_after_exceptions(dep):
    lock = dep.make_lock("alpha")
    with pytest.raises(RuntimeError):
        with lock:
            raise RuntimeError("boom")
    # a balanced stack means a later acquire records no bogus edge
    with lock:
        pass
    assert dep.report()["edges"] == []


# ---------------------------------------------------------------------------
# bit-identity: instrumented engine == plain engine
# ---------------------------------------------------------------------------

def _threaded_workload():
    """4 tenants x 4 threads: ingest chains + queries, deterministic
    per tenant because single-tenant ingests apply in submission
    order.  Returns {tenant: query result} as numpy arrays."""
    scheme = CombinationScheme(2, 3)
    names = [f"t{i}" for i in range(4)]
    eng = CTEngine()
    for i, name in enumerate(names):
        rng = np.random.default_rng(100 + i)
        grids = {ell: rng.standard_normal(grid_shape(ell))
                 for ell, _ in scheme.grids}
        eng.register(name, scheme, grids)
    eng.start()

    def work(name, i):
        rng = np.random.default_rng(200 + i)
        for _ in range(3):
            grids = {ell: rng.standard_normal(grid_shape(ell))
                     for ell, _ in scheme.grids}
            eng.submit_ingest(name, grids).result(30)

    threads = [threading.Thread(target=work, args=(n, i))
               for i, n in enumerate(names)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(60)
    pts = np.random.default_rng(7).random((16, 2))
    out = {n: np.asarray(eng.submit_query(n, pts).result(30))
           for n in names}
    eng.stop()
    return out


def test_instrumented_engine_bit_identical():
    clear_compile_cache()
    lockdep.disable()       # uninstrumented baseline, even in the
    try:                    # REPRO_LOCKDEP=1 CI run
        plain = _threaded_workload()
        lockdep.enable()
        lockdep.reset()
        instrumented = _threaded_workload()
        assert lockdep.report()["cycles"] == []
        assert [v for v in lockdep.violations()
                if v["rule"] != "lock-cycle"] == []
    finally:
        lockdep.reset()
        lockdep.restore_default()
    for name in plain:
        assert np.array_equal(plain[name], instrumented[name]), name
