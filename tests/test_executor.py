"""Batched CT executor == dict-based communication phase, single-jit proof,
bucketing edge cases."""

import math
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from proptest import cases, integers, seeds

from repro.core import combination as comb
from repro.core.executor import (build_plan, ct_embedded, ct_scatter,
                                 ct_transform)
from repro.core.levels import (CombinationScheme, LevelVector,
                               canonical_levels, grid_shape)
from repro.kernels.hierarchize import (hierarchize_batched,
                                       hierarchize_batched_jnp)
from repro.kernels.ops import dehierarchize, hierarchize


def _random_grids(scheme, rng):
    return {ell: jnp.asarray(rng.standard_normal(grid_shape(ell)))
            for ell, _ in scheme.grids}


def _dict_gather(grids, scheme):
    hier = {ell: hierarchize(u, "ref") for ell, u in grids.items()}
    return comb.combine_full(hier, scheme)[0]


# ---------------------------------------------------------------------------
# (a) equivalence with the dict path, d in {2, 3, 4}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dim,level,seed", cases(
    lambda r: (integers(r, 2, 3), integers(r, 2, 3), seeds(r)), n=6) + [
        (2, 4, 11), (2, 5, 12), (4, 2, 13), (4, 3, 14),
        pytest.param(3, 4, 15, marks=pytest.mark.slow),
        pytest.param(4, 4, 16, marks=pytest.mark.slow)])
def test_ct_transform_matches_dict_path(dim, level, seed):
    scheme = CombinationScheme(dim, level)
    grids = _random_grids(scheme, np.random.default_rng(seed))
    want = np.asarray(_dict_gather(grids, scheme))
    got = np.asarray(ct_transform(grids, scheme))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("dim,level", [
    (2, 4), (3, 3),
    pytest.param(2, 5, marks=pytest.mark.slow),
    pytest.param(3, 4, marks=pytest.mark.slow),
    pytest.param(4, 3, marks=pytest.mark.slow)])
def test_ct_scatter_matches_dict_path(dim, level):
    """Scatter phase: executor == subspace-gather/scatter + dehierarchize."""
    scheme = CombinationScheme(dim, level)
    grids = _random_grids(scheme, np.random.default_rng(1))
    hier = {ell: hierarchize(u, "ref") for ell, u in grids.items()}
    combined = comb.gather_subspaces(hier, scheme)
    scattered = comb.scatter_subspaces(combined, scheme)
    want = {ell: dehierarchize(a, "ref") for ell, a in scattered.items()}
    got = ct_scatter(ct_transform(grids, scheme), scheme)
    assert set(got) == set(want)
    for ell in got:
        np.testing.assert_allclose(np.asarray(got[ell]),
                                   np.asarray(want[ell]),
                                   rtol=1e-11, atol=1e-12)


def test_ct_embedded_matches_embed_loop():
    """Unweighted per-grid embedded surpluses == embed_to_full per grid,
    and their coefficient-weighted sum == ct_transform."""
    scheme = CombinationScheme(3, 3)
    grids = _random_grids(scheme, np.random.default_rng(2))
    embedded, coeffs, order = ct_embedded(grids, scheme)
    assert embedded.shape[0] == len(order) == len(scheme.grids)
    full_levels = build_plan(scheme).full_levels
    for g, ell in enumerate(order):
        want = comb.embed_to_full(hierarchize(grids[ell], "ref"), ell,
                                  full_levels)
        np.testing.assert_allclose(np.asarray(embedded[g]), np.asarray(want),
                                   rtol=1e-12, atol=1e-12)
    via_sum = jnp.tensordot(coeffs.astype(embedded.dtype), embedded,
                            axes=[[0], [0]])
    np.testing.assert_allclose(np.asarray(via_sum),
                               np.asarray(ct_transform(grids, scheme)),
                               rtol=1e-12, atol=1e-12)


@pytest.mark.slow
def test_high_dim_scheme():
    """d=10 exercises the jnp (no-tile-padding) batched path end to end
    (pallas==jnp numerics are also pinned fast by
    test_batched_pallas_matches_jnp)."""
    scheme = CombinationScheme(10, 2)
    grids = _random_grids(scheme, np.random.default_rng(3))
    want = np.asarray(_dict_gather(grids, scheme))
    got = np.asarray(ct_transform(grids, scheme))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# (b) the whole pipeline is ONE jitted function
# ---------------------------------------------------------------------------

def test_single_trace_single_cache_entry():
    """New grid VALUES never retrace: the bucket plan and index maps are
    trace-time constants, so the jit cache holds exactly one entry per
    scheme shape signature regardless of grid count."""
    scheme = CombinationScheme(3, 4)      # 22 grids -> must stay 1 trace
    traces = []

    def fn(nodal_grids):
        traces.append(1)
        return ct_transform(nodal_grids, scheme)

    jitted = jax.jit(fn)
    out1 = jitted(_random_grids(scheme, np.random.default_rng(0)))
    out2 = jitted(_random_grids(scheme, np.random.default_rng(1)))
    jax.block_until_ready((out1, out2))
    assert len(traces) == 1
    assert jitted._cache_size() == 1
    assert not np.allclose(np.asarray(out1), np.asarray(out2))


def test_make_ct_step_jits_once():
    from repro.launch.steps import make_ct_step
    scheme = CombinationScheme(2, 4)
    step = make_ct_step(scheme)
    a = step(_random_grids(scheme, np.random.default_rng(0)))
    b = step(_random_grids(scheme, np.random.default_rng(4)))
    jax.block_until_ready((a, b))
    assert step._cache_size() == 1
    want = _dict_gather(_random_grids(scheme, np.random.default_rng(4)),
                        scheme)
    np.testing.assert_allclose(np.asarray(b), np.asarray(want),
                               rtol=1e-12, atol=1e-12)


def test_make_ct_eval_step_fused_transform_eval():
    """The one-shot transform+eval step == ct_transform followed by
    hierarchical-basis interpolation (and == the direct interpolant)."""
    from repro.core.interpolation import interpolate_hierarchical
    from repro.launch.steps import make_ct_eval_step
    scheme = CombinationScheme(2, 4)
    grids = _random_grids(scheme, np.random.default_rng(9))
    pts = jnp.asarray(np.random.default_rng(10).random((32, 2)))
    step = make_ct_eval_step(scheme)
    got = step(grids, pts)
    want = interpolate_hierarchical(ct_transform(grids, scheme), pts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-12, atol=1e-12)
    direct = comb.combined_interpolant_points(grids, scheme, pts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(direct),
                               rtol=1e-8, atol=1e-9)
    assert step._cache_size() == 1


# ---------------------------------------------------------------------------
# (c) bucketing edge cases
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class _FakeScheme:
    """Minimal scheme stand-in: arbitrary (level vector, coefficient) sets."""
    dim: int
    grids: Tuple[Tuple[LevelVector, int], ...]


def test_all_singleton_buckets():
    """A scheme where no two grids share a shape (even up to transposition)
    degrades to one launch per grid but stays exact."""
    scheme = _FakeScheme(2, (((1, 2), 1), ((1, 3), -1), ((2, 3), 1),
                             ((3, 3), 1)))
    plan = build_plan(scheme)
    assert len(plan.buckets) == 4
    assert all(len(b.ells) == 1 for b in plan.buckets)
    rng = np.random.default_rng(5)
    grids = {ell: jnp.asarray(rng.standard_normal(grid_shape(ell)))
             for ell, _ in scheme.grids}
    hier = {ell: hierarchize(u, "ref") for ell, u in grids.items()}
    want = np.asarray(comb.combine_full(hier, scheme)[0])
    np.testing.assert_allclose(np.asarray(ct_transform(grids, scheme)),
                               want, rtol=1e-12, atol=1e-12)


def test_transposed_grids_share_bucket():
    """All axis-permutations of one level multiset land in one bucket."""
    scheme = CombinationScheme(3, 3)      # diagonal |ell|=5 has (3,1,1) perms
    plan = build_plan(scheme)
    n_perm_classes = len({tuple(sorted(ell, reverse=True))
                          for ell, _ in scheme.grids})
    assert len(plan.buckets) == n_perm_classes
    assert plan.num_grids == len(scheme.grids)
    for b in plan.buckets:
        for ell, perm, canon in zip(b.ells, b.perms, b.levels):
            assert tuple(ell[p] for p in perm) == canon
            assert canonical_levels(ell)[0] == tuple(sorted(ell,
                                                            reverse=True))


def test_bucket_count_collapses_in_high_dim():
    """The reason bucketing matters: d=10 diagonals are almost entirely
    permutations of each other (55 grids on |ell|=12 -> 2 buckets)."""
    plan = build_plan(CombinationScheme(10, 3))
    # diagonals: |ell|=12 (C(11,9)=55 grids), |ell|=11 (10), |ell|=10 (1)
    assert plan.num_grids == math.comb(11, 9) + math.comb(10, 9) + 1
    # level multisets: (3,1^9), (2,2,1^8) | (2,1^9) | (1^10)
    assert len(plan.buckets) == 4


def test_index_plan_covers_grid_points_exactly():
    """Every non-pad position maps into the fine buffer exactly where
    embed_to_full writes; pads map to the dump slot."""
    scheme = CombinationScheme(2, 4)
    plan = build_plan(scheme)
    fine_size = plan.fine_size
    for b in plan.buckets:
        for g, ell in enumerate(b.ells):
            n_real = int(np.prod(grid_shape(ell)))
            idx = b.index[g]
            real = idx[idx < fine_size]
            assert len(real) == n_real
            assert len(set(real.tolist())) == n_real  # injective
            assert (idx[idx >= fine_size] == fine_size).all()


def test_ct_surrogate_serving():
    """serve.CTSurrogate answers point queries with the combined
    interpolant (== the direct weighted sum of multilinear interpolants)."""
    from repro.core.interpolation import sample_function
    from repro.launch.serve import CTSurrogate
    scheme = CombinationScheme(2, 5)
    u = lambda a, b: jnp.sin(2 * a) * (b - b * b)
    grids = {ell: sample_function(u, ell) for ell, _ in scheme.grids}
    srv = CTSurrogate(scheme, grids)
    pts = np.random.default_rng(8).random((64, 2))
    want = np.asarray(comb.combined_interpolant_points(
        grids, scheme, jnp.asarray(pts)))
    np.testing.assert_allclose(srv.query(pts), want, rtol=1e-9, atol=1e-10)
    # update with new state re-uses the jitted ingest (no retrace)
    grids2 = {ell: 2.0 * g for ell, g in grids.items()}
    srv.update(grids2)
    np.testing.assert_allclose(srv.query(pts), 2 * want,
                               rtol=1e-9, atol=1e-10)
    assert srv._ingest._cache_size() == 1


# ---------------------------------------------------------------------------
# batched kernels: pallas path == jnp path (incl. padded members)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,levels", [
    ((7, 15), ((3, 4), (3, 4))),
    ((15, 15), ((3, 4), (4, 4))),         # first member padded along axis 0
    ((7, 7, 7), ((3, 3, 3), (3, 2, 1))),  # mixed sub-target levels
])
def test_batched_pallas_matches_jnp(shape, levels):
    rng = np.random.default_rng(6)
    x = np.zeros((len(levels),) + shape)
    for g, lv in enumerate(levels):
        sl = tuple(slice(0, (1 << l) - 1) for l in lv)
        x[g][sl] = rng.standard_normal(tuple((1 << l) - 1 for l in lv))
    xj = jnp.asarray(x)
    a = np.asarray(hierarchize_batched(xj, levels, method="pallas"))
    b = np.asarray(hierarchize_batched_jnp(xj, levels))
    np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-14)
    for g, lv in enumerate(levels):
        sl = tuple(slice(0, (1 << l) - 1) for l in lv)
        want = np.asarray(hierarchize(xj[g][sl], "ref"))
        np.testing.assert_allclose(a[g][sl], want, rtol=1e-12, atol=1e-13)
