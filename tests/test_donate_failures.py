"""``ExecSpec(donate=True)`` x failure paths: a donated-then-failed
ingest must never resubmit or retain deleted device buffers — the owning
future resolves with the named ``IngestBuffersDonated`` error instead.
Covers the ``check_finite`` NaN spelling, the rebind-race retry
spelling, and the cluster failover spelling (whose ``np.asarray``
snapshots make resubmission donation-safe by construction)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.engine import (CTEngine, ExecSpec, IngestBuffersDonated,
                               clear_compile_cache)
from repro.core.levels import CombinationScheme, grid_shape

SCHEME = CombinationScheme(2, 3)


@pytest.fixture(autouse=True)
def _fresh_caches():
    clear_compile_cache()
    yield


def _host_grids(seed):
    rng = np.random.default_rng(seed)
    return {ell: rng.standard_normal(grid_shape(ell))
            for ell, _ in SCHEME.grids}


def test_nan_ingest_with_donation_resolves_named_error():
    """check_finite catches the NaN only AFTER the executable consumed
    (and possibly donated) the inputs — with ``donate=True`` the failure
    is unretryable, so it surfaces as ``IngestBuffersDonated``, not the
    retryable ``FloatingPointError``.  The tenant keeps serving its
    last good surplus and the engine stays healthy."""
    eng = CTEngine(ExecSpec(donate=True), check_finite=True)
    eng.register("t", SCHEME, _host_grids(0))
    good = np.asarray(eng.surplus("t"))

    bad = _host_grids(1)
    ell = next(iter(bad))
    bad[ell] = bad[ell].copy()
    bad[ell].flat[0] = np.nan
    with pytest.raises(IngestBuffersDonated, match="non-finite.*donated"):
        eng.update("t", bad)
    np.testing.assert_array_equal(np.asarray(eng.surplus("t")), good)

    # without donation the same fault stays the retryable named error
    eng2 = CTEngine(check_finite=True)
    eng2.register("t", SCHEME, _host_grids(0))
    with pytest.raises(FloatingPointError, match="non-finite"):
        eng2.update("t", bad)


def test_rebind_race_retry_never_redispatches_donated_buffers():
    """The CAS retry loop in ``_ingest_one``: when a concurrent rebind
    swaps the tenant record mid-flight AND the first attempt's staged
    device buffers were donated (deleted), the retry must raise the
    named error instead of handing XLA dead buffers."""
    eng = CTEngine(ExecSpec(donate=True))
    eng.register("t", SCHEME, _host_grids(2))

    staged = {ell: jnp.asarray(v) for ell, v in _host_grids(3).items()}
    orig = eng._dispatch_ingest
    fired = []

    def racy(tenant, nodal_grids):
        out = orig(tenant, nodal_grids)
        if not fired:
            fired.append(True)
            jax.block_until_ready(out)
            # simulate a backend that honored the donation (CPU may
            # only warn): the staged inputs are gone after dispatch
            for v in staged.values():
                if not v.is_deleted():
                    v.delete()
            # concurrent rebind swaps the record -> the commit CAS
            # fails and _ingest_one loops for a retry
            eng.rebind("t", axis_name="row")
        return out

    eng._dispatch_ingest = racy
    with pytest.raises(IngestBuffersDonated, match="donated.*deleted"):
        eng.update("t", staged)
    assert fired     # the race actually happened


def test_explicitly_deleted_payload_fails_named_not_xla():
    """Even the FIRST attempt guards: a donated-spec ingest handed
    already-deleted device buffers resolves with the named error, not
    an XLA crash."""
    eng = CTEngine(ExecSpec(donate=True))
    eng.register("t", SCHEME, _host_grids(4))
    staged = {ell: jnp.asarray(v) for ell, v in _host_grids(5).items()}
    for v in staged.values():
        jax.block_until_ready(v)
        if not v.is_deleted():
            v.delete()
    with pytest.raises(IngestBuffersDonated, match="donated"):
        eng.update("t", staged)


@pytest.mark.cluster
def test_cluster_failover_retry_is_donation_safe():
    """The PR-7 host-kill retry spelling: the cluster snapshots every
    payload host-side (``np.asarray``), so each engine stages FRESH
    device buffers per dispatch and a failover resubmission after a
    donated ingest never touches deleted memory — the promoted future
    resolves with a value, not ``IngestBuffersDonated``."""
    from repro.runtime.cluster import CTCluster
    cl = CTCluster(4, replication=2, seed=11,
                   spec=ExecSpec(donate=True))
    cl.register("t", SCHEME, _host_grids(6))
    pts = np.random.default_rng(60).random((8, 2))
    base = cl.query("t", pts)
    cl.start()
    try:
        victim = cl.owners_of("t")[0]
        fut = cl.submit_ingest("t", _host_grids(7))
        cl.injector.kill(victim)
        surplus = fut.result(60)         # replica ack resolves it
        assert np.all(np.isfinite(np.asarray(surplus)))
        after = cl.query("t", pts)
        assert not np.array_equal(after, base)
        assert cl.stats()["host_failed"] == 0
    finally:
        cl.stop()
