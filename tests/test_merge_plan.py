"""Cost-model-driven bucket merging + fused scatter-add epilogue.

Three layers:

  (a) merge-plan invariants — pure numpy, no devices: every scheme grid
      lands in exactly one super-bucket slot, pad positions all route to
      the dump slot, the partition is contiguous in the descending shape
      order, and incremental rebuilds of merged plans are bit-identical
      to from-scratch merged builds.
  (b) seeded end-to-end property tests of below-target (padded) bucket
      members: merged+fused ``ct_transform`` bit-identical (f64; 1e-6 at
      f32) to the unmerged unfused path over random downward-closed
      schemes, ``ct_scatter`` / ``ct_embedded`` through merged plans
      against the unmerged oracle.
  (c) the sharded gather consuming the same fused epilogue with per-slab
      local maps (multidevice tier).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from proptest import cases, integers, seeds

from repro.core.executor import (MergeConfig, build_plan, bucket_surpluses,
                                 bucket_tail_surpluses, ct_embedded_with_plan,
                                 ct_scatter_with_plan, ct_transform,
                                 ct_transform_with_plan, extend_plan,
                                 plan_fused_ok, plan_launch_stats, shard_plan,
                                 update_plan_coefficients)
from repro.core.levels import (CombinationScheme, GeneralScheme,
                               admissible_extensions, canonical_levels,
                               grid_shape)

#: merge everything the member cap allows: launch overhead priced far above
#: any pad waste at test scale, so below-target members are guaranteed
AGGRESSIVE = MergeConfig(launch_cost_bytes=1 << 30)
#: pure pad-waste pricing: launches are free, so nothing should merge
NO_MERGE_GAIN = MergeConfig(launch_cost_bytes=0)


def _random_general_scheme(seed, dim, steps, max_level=4):
    rng = np.random.default_rng(seed)
    gs = GeneralScheme.regular(dim, 1)
    for _ in range(steps):
        cands = [c for c in admissible_extensions(gs.index_set)
                 if max(c) <= max_level]
        if not cands:
            break
        gs = gs.with_levels([cands[int(rng.integers(len(cands)))]])
    return gs


def _random_grids(scheme, rng, dtype=np.float64):
    return {ell: jnp.asarray(rng.standard_normal(grid_shape(ell)), dtype)
            for ell, _ in scheme.grids}


# ---------------------------------------------------------------------------
# (a) merge-plan invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dim,steps,seed", cases(
    lambda r: (integers(r, 2, 4), integers(r, 2, 10), seeds(r)), n=10))
def test_every_member_in_exactly_one_super_bucket(dim, steps, seed):
    gs = _random_general_scheme(seed, dim, steps)
    plan = build_plan(gs, merge=AGGRESSIVE)
    slots = [(ell, g) for b in plan.buckets for g, ell in enumerate(b.ells)]
    assert len(slots) == len(gs.grids)
    assert sorted(ell for ell, _ in slots) == sorted(ell for ell, _ in
                                                     gs.grids)
    # contiguity: buckets stay sorted by descending canonical target, and
    # member canonical keys never interleave across buckets
    targets = [b.target for b in plan.buckets]
    assert targets == sorted(targets, reverse=True)
    key_seq = [canonical_levels(ell)[0] for b in plan.buckets
               for ell in b.ells]
    assert key_seq == sorted(key_seq, reverse=True)


@pytest.mark.parametrize("dim,steps,seed", cases(
    lambda r: (integers(r, 2, 3), integers(r, 3, 10), seeds(r)), n=8))
def test_merged_index_maps_route_pads_to_dump(dim, steps, seed):
    """Below-target members: real positions inject into the fine buffer,
    every pad position of the padded canonical array hits the dump slot."""
    gs = _random_general_scheme(seed, dim, steps)
    plan = build_plan(gs, merge=AGGRESSIVE)
    assert any(len(set(b.levels)) > 1 for b in plan.buckets), \
        "aggressive merge produced no below-target members"
    for b in plan.buckets:
        for g, ell in enumerate(b.ells):
            n_real = int(np.prod(grid_shape(ell)))
            idx = b.index[g]
            real = idx[idx < plan.fine_size]
            assert len(real) == n_real
            assert len(set(real.tolist())) == n_real      # injective
            assert (idx[idx >= plan.fine_size] == plan.fine_size).all()


def test_merge_cost_model_extremes():
    """Launch-dominated pricing merges everything (one super-bucket);
    zero launch cost keeps the exact-canonical partition."""
    scheme = CombinationScheme(3, 4)
    base = build_plan(scheme)
    assert len(build_plan(scheme, merge=AGGRESSIVE).buckets) == 1
    free = build_plan(scheme, merge=NO_MERGE_GAIN)
    assert [b.target for b in free.buckets] == [b.target for b in
                                                base.buckets]
    capped = build_plan(scheme,
                        merge=MergeConfig(launch_cost_bytes=1 << 30,
                                          max_members=3))
    assert len(capped.buckets) > 1
    assert all(len(b.ells) <= max(3, max(len(g.ells) for g in base.buckets))
               for b in capped.buckets)


def test_merge_reduces_launches_wide_diagonal():
    """The ROADMAP acceptance shape: d=10 wide diagonal, >= 2x fewer
    dispatches under the default cost model."""
    scheme = CombinationScheme(10, 2)
    s0 = plan_launch_stats(build_plan(scheme))
    s1 = plan_launch_stats(build_plan(scheme, merge=MergeConfig()))
    assert s1["buckets"] < s0["buckets"]
    assert s0["launches"] >= 2 * s1["launches"]


@pytest.mark.parametrize("dim,steps,seed", cases(
    lambda r: (integers(r, 2, 3), integers(r, 2, 8), seeds(r)), n=6))
def test_extend_merged_plan_bit_identical_to_scratch(dim, steps, seed):
    """extend_plan on a merged plan == from-scratch merged build of the
    extended scheme, array for array; surviving buckets reused."""
    gs = _random_general_scheme(seed, dim, steps)
    plan = build_plan(gs, merge=AGGRESSIVE)
    adds = [c for c in admissible_extensions(gs.index_set) if max(c) <= 4][:2]
    if not adds:
        pytest.skip("frontier exhausted")
    gs2 = gs.with_levels(adds)
    inc = extend_plan(plan, gs2)
    scratch = build_plan(gs2, merge=AGGRESSIVE)
    assert inc.merge == scratch.merge == AGGRESSIVE
    assert len(inc.buckets) == len(scratch.buckets)
    for a, b in zip(inc.buckets, scratch.buckets):
        assert a.ells == b.ells and a.target == b.target
        assert a.perms == b.perms and a.levels == b.levels
        np.testing.assert_array_equal(a.coeffs, b.coeffs)
        np.testing.assert_array_equal(a.index, b.index)


def test_extend_plan_identity_reuse_with_duplicate_targets():
    """Two super-buckets may share a componentwise-max target (the member
    cap splits a run); identity reuse is keyed by the member tuple, so an
    unchanged scheme still returns EVERY bucket by object identity."""
    from dataclasses import dataclass
    from typing import Tuple

    @dataclass(frozen=True)
    class _FakeScheme:
        dim: int
        grids: Tuple

    gs = _FakeScheme(2, (((3, 2), 1), ((2, 3), 1), ((3, 1), 1),
                         ((1, 3), 1), ((2, 2), 1)))
    cfg = MergeConfig(launch_cost_bytes=1 << 30, max_members=3)
    plan = build_plan(gs, merge=cfg)
    targets = [b.target for b in plan.buckets]
    assert len(targets) != len(set(targets)), \
        "expected a duplicate-target partition for this scheme/config"
    again = extend_plan(plan, gs)
    assert all(a is b for a, b in zip(plan.buckets, again.buckets))


def test_coefficient_update_keeps_super_buckets():
    gs = GeneralScheme.regular(3, 3)
    plan = build_plan(gs, merge=AGGRESSIVE)
    dropped = max(ell for ell, _ in gs.grids)
    upd = update_plan_coefficients(plan, gs.without_levels([dropped]))
    assert upd.merge == AGGRESSIVE
    assert all(a.index is b.index for a, b in zip(plan.buckets, upd.buckets))
    assert all(a.ells == b.ells for a, b in zip(plan.buckets, upd.buckets))


def test_merged_shard_plan_partitions_like_base():
    """shard_plan on a merged plan: every non-pad entry of every merged
    index map still lands in exactly one slab."""
    gs = GeneralScheme.regular(3, 3)
    plan = build_plan(gs, merge=AGGRESSIVE)
    splan = shard_plan(plan, 5)
    for b, sb in zip(plan.buckets, splan.slab_buckets):
        hits = np.zeros(b.index.shape, np.int64)
        for s in range(5):
            hits += sb.index[s] != splan.slab_size
        pad = b.index == plan.fine_size
        assert np.all(hits[~pad] == 1)
        assert np.all(hits[pad] == 0)


# ---------------------------------------------------------------------------
# (b) end-to-end: padded members through transform / scatter / embedded
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dim,steps,dtype,seed", cases(
    lambda r: (integers(r, 2, 3), integers(r, 2, 8),
               ("float32", "float64")[integers(r, 0, 1)], seeds(r)), n=12))
def test_merged_fused_transform_matches_unmerged(dim, steps, dtype, seed):
    """Random downward-closed schemes x dtypes: merged plan + fused
    epilogue == unmerged unfused path — bit-identical at f64, 1e-6 at
    f32 (the fused epilogue and the 3-term kernels are bitwise exact;
    the f32 tolerance only covers platforms whose scatter departs)."""
    gs = _random_general_scheme(seed, dim, steps)
    grids = _random_grids(gs, np.random.default_rng(seed), np.dtype(dtype))
    plain = build_plan(gs)
    merged = build_plan(gs, merge=AGGRESSIVE)
    want = np.asarray(ct_transform_with_plan(grids, plain, fused=False))
    for plan, fused in ((plain, True), (merged, None), (merged, False)):
        got = np.asarray(ct_transform_with_plan(grids, plan, fused=fused))
        assert got.dtype == want.dtype
        if dtype == "float64":
            np.testing.assert_array_equal(got, want)
        else:
            np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dim,level", [(2, 4), (3, 3)])
def test_merged_scatter_matches_unmerged(dim, level):
    """Scatter phase through a merged plan: below-target members read
    their strided slots and dehierarchize with the padded inverse
    operators — equal to the unmerged scatter on every grid."""
    scheme = CombinationScheme(dim, level)
    grids = _random_grids(scheme, np.random.default_rng(1))
    full = ct_transform(grids, scheme)
    want = ct_scatter_with_plan(full, build_plan(scheme))
    got = ct_scatter_with_plan(full, build_plan(scheme, merge=AGGRESSIVE))
    assert set(got) == set(want)
    for ell in got:
        np.testing.assert_allclose(np.asarray(got[ell]),
                                   np.asarray(want[ell]),
                                   rtol=1e-12, atol=1e-12)


def test_merged_embedded_matches_unmerged():
    """The vectorized member-axis embed: per-grid embedded surpluses off a
    merged plan (pads -> dump) == the unmerged plan's, grid for grid."""
    scheme = CombinationScheme(3, 3)
    grids = _random_grids(scheme, np.random.default_rng(2))
    e0, c0, o0 = ct_embedded_with_plan(grids, build_plan(scheme))
    e1, c1, o1 = ct_embedded_with_plan(grids,
                                       build_plan(scheme, merge=AGGRESSIVE))
    g0 = {ell: np.asarray(e0[i]) for i, ell in enumerate(o0)}
    g1 = {ell: np.asarray(e1[i]) for i, ell in enumerate(o1)}
    cc0 = {ell: c0[i] for i, ell in enumerate(o0)}
    cc1 = {ell: c1[i] for i, ell in enumerate(o1)}
    assert set(g0) == set(g1)
    for ell in g0:
        assert cc0[ell] == cc1[ell]
        np.testing.assert_array_equal(g0[ell], g1[ell])


def test_fused_epilogue_engages_on_pallas_plan():
    """A near-square scheme takes the Pallas path end to end: the fused
    default removes the compact-stack round trip from the plan-derived
    accounting and stays bit-identical to every other path."""
    gs = GeneralScheme.from_levels([(6, 5), (5, 6)], close=True)
    plan = build_plan(gs)
    assert plan_fused_ok(plan)
    s_unfused = plan_launch_stats(plan, fused=False)
    s_fused = plan_launch_stats(plan)
    assert s_fused["stack_bytes"] == 0 < s_unfused["stack_bytes"]
    assert s_fused["scatter_dispatches"] == 0
    grids = _random_grids(gs, np.random.default_rng(4))
    want = np.asarray(ct_transform_with_plan(grids, plan, fused=False))
    np.testing.assert_array_equal(
        np.asarray(ct_transform_with_plan(grids, plan)), want)
    merged = build_plan(gs, merge=MergeConfig())
    np.testing.assert_array_equal(
        np.asarray(ct_transform_with_plan(grids, merged)), want)


def test_fused_transform_jits_once():
    """The fused epilogue keeps the one-trace contract of the executor."""
    gs = GeneralScheme.from_levels([(6, 5), (5, 6)], close=True)
    plan = build_plan(gs, merge=MergeConfig())
    traces = []

    def fn(grids):
        traces.append(1)
        return ct_transform_with_plan(grids, plan)

    jitted = jax.jit(fn)
    out1 = jitted(_random_grids(gs, np.random.default_rng(0)))
    out2 = jitted(_random_grids(gs, np.random.default_rng(1)))
    jax.block_until_ready((out1, out2))
    assert len(traces) == 1 and jitted._cache_size() == 1


# ---------------------------------------------------------------------------
# (c) sharded gather through merged plans / fused epilogue
# ---------------------------------------------------------------------------

def _mesh(n, name="slab"):
    from repro.compat import AxisType, make_mesh
    return make_mesh((n,), (name,), devices=np.array(jax.devices()[:n]),
                     axis_types=(AxisType.Auto,))


@pytest.mark.multidevice
@pytest.mark.parametrize("dim,steps,n_groups,seed", cases(
    lambda r: (integers(r, 2, 3), integers(r, 2, 8), integers(r, 2, 8),
               seeds(r)), n=6))
def test_sharded_gather_merged_plan_matches_single_device(dim, steps,
                                                          n_groups, seed):
    """Slab-sharded gather off a MERGED plan (padded members routed via
    per-slab local maps) == single-device unmerged ct_transform, bitwise."""
    from repro.core.distributed import ct_transform_sharded
    gs = _random_general_scheme(seed, dim, steps)
    grids = _random_grids(gs, np.random.default_rng(seed))
    splan = shard_plan(build_plan(gs, merge=AGGRESSIVE), n_groups)
    want = np.asarray(ct_transform(grids, gs))
    got = np.asarray(ct_transform_sharded(grids, gs, _mesh(n_groups), "slab",
                                          sharded_plan=splan))
    np.testing.assert_array_equal(got, want)


@pytest.mark.multidevice
@pytest.mark.parametrize("n_groups", [2, 5, 8])
def test_sharded_fused_epilogue_matches_unfused(n_groups):
    """gather_slab_scatter_fused (per-slab local maps through the fused
    kernel) == gather_slab_scatter (compact stacks + .at[].add), bitwise,
    ragged slabs included."""
    from repro.core.distributed import (gather_slab_scatter,
                                        gather_slab_scatter_fused)
    gs = GeneralScheme.from_levels([(6, 5), (5, 6)], close=True)
    grids = _random_grids(gs, np.random.default_rng(n_groups))
    splan = shard_plan(build_plan(gs), n_groups)
    assert plan_fused_ok(splan)
    mesh = _mesh(n_groups)
    want = np.asarray(gather_slab_scatter(
        bucket_surpluses(grids, splan), splan, mesh, "slab"))
    got = np.asarray(gather_slab_scatter_fused(
        bucket_tail_surpluses(grids, splan), splan, mesh, "slab"))
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(want, np.asarray(ct_transform(grids, gs)))
