"""Multi-device behaviour, in process on the 8 fake host devices that
``conftest.py`` configures via XLA_FLAGS before jax initializes (the old
subprocess-per-test harness respawned python + jax for every case; the
``multidevice`` marker now gates the whole tier instead)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import AxisType, make_mesh, set_mesh
from repro.core import combination as comb
from repro.core.distributed import (comm_phase_sharded, ct_transform_psum,
                                    ct_transform_sharded,
                                    hierarchize_sharded)
from repro.core.executor import build_plan, ct_transform, shard_plan
from repro.core.levels import (CombinationScheme, GeneralScheme, grid_shape)
from repro.kernels.ops import hierarchize

pytestmark = pytest.mark.multidevice


@pytest.fixture
def no_x64():
    """Model-path tests ran WITHOUT x64 under the old subprocess harness
    (conftest enables it globally for the CT oracles); the transformer
    decode path also miscompiles with 64-bit index types.  Scoping the
    flag per-test keeps both worlds in one process."""
    disable = getattr(jax.experimental, "disable_x64", None)
    if disable is not None:
        with disable():
            yield
        return
    jax.config.update("jax_enable_x64", False)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", True)


def _mesh8():
    return make_mesh((8,), ("grid",), axis_types=(AxisType.Auto,))


def test_sharded_hierarchization_matches_local():
    mesh = _mesh8()
    level0 = 5
    x = np.random.default_rng(0).standard_normal((1 << level0, 15, 7))
    x[-1] = 0.0
    out = hierarchize_sharded(jnp.asarray(x), level0, mesh, "grid")
    want = hierarchize(jnp.asarray(x[:-1]), "ref")
    np.testing.assert_allclose(np.asarray(out)[:-1], np.asarray(want),
                               rtol=1e-9, atol=1e-10)


def test_distributed_comm_phase_matches_serial():
    mesh = _mesh8()
    scheme = CombinationScheme(2, 5)
    rng = np.random.default_rng(1)
    hier = {ell: hierarchize(jnp.asarray(
        rng.standard_normal(grid_shape(ell))), "ref")
        for ell, _ in scheme.grids}
    combined = comb.gather_subspaces(hier, scheme)
    want = comb.scatter_subspaces(combined, scheme)
    got = comm_phase_sharded(hier, scheme, mesh, "grid")
    for ell in got:
        np.testing.assert_allclose(np.asarray(got[ell]),
                                   np.asarray(want[ell]),
                                   rtol=1e-8, atol=1e-9)


def test_comm_phase_slab_sharded_matches_serial():
    """The same comm phase through the slab-sharded gather (no
    ``(G, *fine_shape)`` stack) == the psum realization == serial."""
    mesh = _mesh8()
    scheme = CombinationScheme(2, 5)
    rng = np.random.default_rng(1)
    hier = {ell: hierarchize(jnp.asarray(
        rng.standard_normal(grid_shape(ell))), "ref")
        for ell, _ in scheme.grids}
    combined = comb.gather_subspaces(hier, scheme)
    want = comb.scatter_subspaces(combined, scheme)
    splan = shard_plan(build_plan(scheme), 8)
    got = comm_phase_sharded(hier, scheme, mesh, "grid", sharded_plan=splan)
    for ell in got:
        np.testing.assert_allclose(np.asarray(got[ell]),
                                   np.asarray(want[ell]),
                                   rtol=1e-8, atol=1e-9)


def test_ct_transform_psum_matches_serial():
    """Batched executor + psum gather == single-process ct_transform."""
    mesh = _mesh8()
    scheme = CombinationScheme(3, 4)
    rng = np.random.default_rng(2)
    grids = {ell: jnp.asarray(rng.standard_normal(grid_shape(ell)))
             for ell, _ in scheme.grids}
    want = ct_transform(grids, scheme)
    got = ct_transform_psum(grids, scheme, mesh, "grid")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-12, atol=1e-12)


def test_ct_transform_psum_general_scheme():
    """The distributed gather accepts a GeneralScheme (adaptive index set)
    unchanged: psum path == single-process executor path."""
    mesh = _mesh8()
    scheme = GeneralScheme.from_levels(
        [(5, 1, 1), (3, 3, 1), (2, 2, 2), (1, 4, 1)], close=True)
    rng = np.random.default_rng(3)
    grids = {ell: jnp.asarray(rng.standard_normal(grid_shape(ell)))
             for ell, _ in scheme.grids}
    want = ct_transform(grids, scheme)
    got = ct_transform_psum(grids, scheme, mesh, "grid")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-12, atol=1e-12)


def test_ct_transform_sharded_through_psum_entry_point():
    """``ct_transform_psum(..., sharded_plan=)`` routes through the
    slab-sharded gather and is bit-identical to the serial transform."""
    mesh = _mesh8()
    scheme = CombinationScheme(3, 4)
    rng = np.random.default_rng(2)
    grids = {ell: jnp.asarray(rng.standard_normal(grid_shape(ell)))
             for ell, _ in scheme.grids}
    splan = shard_plan(build_plan(scheme), 8)
    want = ct_transform(grids, scheme)
    got = ct_transform_psum(grids, scheme, mesh, "grid", sharded_plan=splan)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ct_transform_sharded_keeps_sharding():
    """``gather=False``: the result stays slab-sharded under a
    NamedSharding, leading axis padded to ``n_slabs * slab_rows``."""
    mesh = _mesh8()
    scheme = CombinationScheme(2, 5)
    rng = np.random.default_rng(4)
    grids = {ell: jnp.asarray(rng.standard_normal(grid_shape(ell)))
             for ell, _ in scheme.grids}
    splan = shard_plan(build_plan(scheme), 8)
    out = ct_transform_sharded(grids, scheme, mesh, "grid",
                               sharded_plan=splan, gather=False)
    assert out.shape[0] == 8 * splan.slab_rows
    assert isinstance(out.sharding, NamedSharding)
    assert out.sharding.spec[0] == "grid"
    want = np.asarray(ct_transform(grids, scheme))
    np.testing.assert_array_equal(np.asarray(out)[:want.shape[0]], want)
    assert np.all(np.asarray(out)[want.shape[0]:] == 0)


@pytest.mark.slow
def test_dp_training_step_matches_single_device(no_x64):
    """8-way DP: global loss equals the 1-device loss on the same batch."""
    from repro.configs import get_smoke_config
    from repro.launch.steps import init_train_state, make_train_step
    from repro.launch import sharding as rules
    from repro.models import model as M
    from repro.models.config import ShapeConfig
    from repro.optim.schedule import constant
    cfg = get_smoke_config("smollm_360m")
    key = jax.random.PRNGKey(0)
    params, opt = init_train_state(key, cfg)
    batch = M.make_batch(cfg, ShapeConfig("t", 32, 8, "train"), key)
    step = make_train_step(cfg, constant(1e-3))
    l1 = float(step(params, opt, batch)[2]["loss"])
    mesh = make_mesh((8, 1), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
    named = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, P))
    sds = jax.eval_shape(lambda: init_train_state(key, cfg))
    ps = rules.param_specs(sds[0], mesh)
    bs = {"tokens": P("data", None), "labels": P("data", None)}
    with mesh:
        jitted = jax.jit(step, in_shardings=(named(ps), None, named(bs)))
        l8 = float(jitted(params, opt, batch)[2]["loss"])
    np.testing.assert_allclose(l8, l1, rtol=2e-4)


@pytest.mark.slow
def test_elastic_remesh_restore(tmp_path, no_x64):
    """Elastic downscale: train 8 steps on an 8-device mesh, checkpoint,
    'lose' half the fleet, restore onto the plan_mesh-chosen 4-device mesh
    and keep training — losses stay finite and the restore is exact."""
    from repro.checkpoint.checkpoint import restore_checkpoint, \
        save_checkpoint
    from repro.configs import get_smoke_config
    from repro.launch import sharding as rules
    from repro.launch.steps import init_train_state, make_train_step
    from repro.models import model as M
    from repro.models.config import ShapeConfig
    from repro.optim.schedule import constant
    from repro.runtime.elastic import plan_mesh

    cfg = get_smoke_config("smollm_360m")
    key = jax.random.PRNGKey(0)
    shape = ShapeConfig("t", 32, 8, "train")
    step = make_train_step(cfg, constant(1e-3))
    ckdir = str(tmp_path)

    def run_on(n_devs, params, opt, steps, start):
        plan = plan_mesh(n_devs, chips_per_pod=8, preferred_model=2)
        mesh = make_mesh(plan.shape(), plan.axes(),
                         axis_types=(AxisType.Auto,) * len(plan.axes()))
        named = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
        sds = jax.eval_shape(lambda: init_train_state(key, cfg))
        psh = named(rules.param_specs(sds[0], mesh))
        osh = named(rules.opt_state_specs(sds[0], mesh))
        params = jax.device_put(params, psh)
        opt = jax.device_put(opt, osh)
        with mesh:
            fn = jax.jit(step, in_shardings=(psh, osh, None),
                         out_shardings=(psh, osh, None))
            losses = []
            for s in range(start, start + steps):
                batch = M.make_batch(cfg, shape,
                                     jax.random.fold_in(key, s))
                params, opt, m = fn(params, opt, batch)
                losses.append(float(m["loss"]))
        return params, opt, losses

    params, opt = init_train_state(key, cfg)
    params, opt, l1 = run_on(8, params, opt, steps=4, start=0)
    save_checkpoint(ckdir, 4, (params, opt))
    # fleet shrinks to 4 devices: restore + continue
    tmpl = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype),
                        (params, opt))
    (params2, opt2), _ = restore_checkpoint(ckdir, 4, tmpl)
    # the restored params are bit-identical to the saved ones
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    params2, opt2, l2 = run_on(4, params2, opt2, steps=4, start=4)
    assert all(np.isfinite(l) for l in l1 + l2), (l1, l2)


@pytest.mark.slow
def test_ep_moe_matches_ragged(no_x64):
    """Expert-parallel shard_map dispatch == exact ragged dispatch at high
    capacity, and gradients flow (the production MoE path, §Perf)."""
    from repro.models.moe import moe_ffn, moe_ffn_ep
    mesh = make_mesh((2, 4), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
    e, d, f, b, s, k = 8, 16, 32, 4, 12, 2
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    params = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * 0.1,
        "wi_gate": jax.random.normal(ks[1], (e, d, f)) * d ** -0.5,
        "wi_up": jax.random.normal(ks[2], (e, d, f)) * d ** -0.5,
        "wo": jax.random.normal(ks[3], (e, f, d)) * f ** -0.5,
    }
    x = jax.random.normal(ks[4], (b, s, d), jnp.float32)
    y_ref, _ = moe_ffn(x.reshape(b * s, d), params, num_experts=e,
                       k=k, impl="ragged")
    with set_mesh(mesh):
        y_ep, _ = jax.jit(lambda x, p: moe_ffn_ep(
            x, p, num_experts=e, k=k, capacity_factor=8.0))(x, params)
        g = jax.jit(jax.grad(lambda p: jnp.sum(moe_ffn_ep(
            x, p, num_experts=e, k=k, capacity_factor=8.0)[0] ** 2)))(
            params)
    np.testing.assert_allclose(np.asarray(y_ep),
                               np.asarray(y_ref).reshape(b, s, d),
                               rtol=2e-4, atol=2e-4)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()


def test_ep_moe_no_mesh_fallback():
    """Without a mesh context moe_ffn_ep returns None and the block falls
    back to ragged — the 1-device smoke path."""
    from repro.models.moe import moe_ffn_ep
    x = jnp.zeros((2, 4, 8))
    params = {"router": jnp.zeros((8, 4))}
    assert moe_ffn_ep(x, params, num_experts=4, k=2) is None


@pytest.mark.slow
def test_dryrun_single_cell_smallpod(no_x64):
    """The dry-run machinery itself (build_cell + analysis) on an 8-chip
    mesh — fast proxy for the 256/512-chip sweep recorded in EXPERIMENTS."""
    from repro.compat import cost_analysis
    from repro.configs import get_config
    from repro.launch.dryrun import build_cell
    from repro.launch.analysis import collective_bytes
    from repro.models.config import ShapeConfig
    cfg = get_config("smollm_360m")
    shape = ShapeConfig("t", 256, 8, "train")
    mesh = make_mesh((4, 2), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
    fn, args = build_cell(cfg, shape, mesh)
    with mesh:
        compiled = fn.lower(*args).compile()
    cost = cost_analysis(compiled)
    assert cost.get("flops", 0) > 0
    coll = collective_bytes(compiled.as_text())
    assert sum(coll.values()) > 0, coll
