"""Slab-sharded vs grid-replicated distributed CT gather.

The grid-replicated psum (``ct_transform_psum``) materializes the full
``(G, *fine_shape)`` embedded stack before its one psum — per-device
embedded memory is ``(G / n) * fine_size`` and does NOT shrink as devices
are added.  The slab-sharded path (``ct_transform_sharded``) replicates
only the COMPACT surpluses (the scheme's point count) and scatter-adds
into a ``ceil(fine_shape[0] / n) * row_size`` slab per device — embedded
memory scales with ``1 / n_groups``.

For each (scheme, n_groups) this benchmark

  * asserts the sharded gather matches single-device ``ct_transform``
    (fp64 here; the multidevice test tier covers fp32 at 1e-6),
  * records the PER-DEVICE embedded-buffer bytes of both realizations —
    derived from the plan (the slab buffer is ``slab_size + 1`` elements,
    measured off the actual scatter target shape) and, when XLA exposes
    it, the compiled peak temp bytes (``memory_analysis``),
  * times both paths end to end on the fake-device mesh (8 host CPU
    devices; wall time on one physical CPU is a smoke signal, the memory
    accounting is the point).

``--mesh-2d`` adds the fully distributed section: the 2-D
(member x slab) mesh ingest (``ct_transform_sharded(member_axis=...)``),
where the HIERARCHIZATION itself is compute-sharded — each device
transforms only its ``ceil(G_b / n_groups)`` member shard of every
compact stack and ships surpluses to slab owners.  Those rows carry the
plan-derived PER-DEVICE ingest FLOPs and bytes (``plan_ingest_stats``);
CI asserts both shrink strictly as the slab axis grows 1 -> 2 -> 4 (no
device ever materializes the full compact surplus stack).

Emits ``BENCH_executor_sharded.json`` (``--json-out`` overrides, empty
string disables).

  PYTHONPATH=src python benchmarks/executor_sharded.py [--mesh-2d]
"""

from __future__ import annotations

import argparse
import json
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        f"{_flags} --xla_force_host_platform_device_count=8".strip()

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from common import peak_temp_bytes, time_call  # noqa: E402

from repro.compat import AxisType, make_mesh  # noqa: E402
from repro.core.distributed import (ct_transform_psum,  # noqa: E402
                                    ct_transform_sharded)
from repro.core.executor import (build_plan, ct_transform,  # noqa: E402
                                 plan_ingest_stats, shard_plan)
from repro.core.levels import (CombinationScheme, grid_shape,  # noqa: E402
                               scheme_total_points)

SCHEMES = [(2, 7), (3, 5), (4, 4)]
GROUPS = [1, 2, 4, 8]
#: 2-D section configs: (members, slabs).  The (1, s) series over
#: s = 1, 2, 4 is the one CI asserts strict per-device scaling on.
MESH2D = [(1, 1), (1, 2), (1, 4), (2, 2), (2, 4)]
DTYPE = np.float64


def _mesh(n):
    return make_mesh((n,), ("slab",), devices=np.array(jax.devices()[:n]),
                     axis_types=(AxisType.Auto,))


def _mesh2d(m, s):
    return make_mesh((m, s), ("member", "slab"),
                     devices=np.array(jax.devices()[:m * s]),
                     axis_types=(AxisType.Auto, AxisType.Auto))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--mesh-2d", action="store_true",
                    help="also run the 2-D (member x slab) compute-"
                         "sharded ingest section")
    ap.add_argument("--json-out", default="BENCH_executor_sharded.json",
                    help="machine-readable results path ('' disables)")
    args = ap.parse_args(argv)

    itemsize = np.dtype(DTYPE).itemsize
    rows = []
    print(f"{'scheme':>8} {'groups':>6} {'fine_MB':>8} {'psum_dev_MB':>12} "
          f"{'slab_dev_MB':>12} {'mem_ratio':>9} {'psum_ms':>9} "
          f"{'slab_ms':>9}")
    for dim, level in SCHEMES:
        scheme = CombinationScheme(dim, level)
        plan = build_plan(scheme)
        g = plan.num_grids
        rng = np.random.default_rng(dim * 100 + level)
        grids = {ell: jnp.asarray(rng.standard_normal(grid_shape(ell)),
                                  DTYPE)
                 for ell, _ in scheme.grids}
        want = np.asarray(ct_transform(grids, scheme))

        for n in GROUPS:
            mesh = _mesh(n)
            splan = shard_plan(plan, n)
            f_psum = jax.jit(lambda gr, m=mesh: ct_transform_psum(
                gr, scheme, m, "slab"))
            f_slab = jax.jit(lambda gr, m=mesh, sp=splan: ct_transform_psum(
                gr, scheme, m, "slab", plan=sp))
            np.testing.assert_allclose(np.asarray(f_slab(grids)), want,
                                       rtol=1e-12, atol=1e-12)
            np.testing.assert_allclose(np.asarray(f_psum(grids)), want,
                                       rtol=1e-12, atol=1e-12)

            # per-device EMBEDDED buffer bytes (the memory this PR shards):
            # psum path stacks ceil(G/n) full fine buffers per device; the
            # slab path's scatter target is slab_size + 1 elements.
            psum_dev = -(-g // n) * plan.fine_size * itemsize
            slab_dev = (splan.slab_size + 1) * itemsize
            # acceptance bound from the GEOMETRY (not the measured buffer):
            # a perfect 1/n split of the leading axis plus at most one
            # ragged fine row of overhang plus the dump slot
            max_elems = ((plan.fine_shape[0] + n - 1) / n * splan.row_size
                         + 1)
            assert slab_dev <= max_elems * itemsize + 1e-9, \
                (slab_dev, max_elems * itemsize)
            slack = max_elems * n / plan.fine_size - 1

            t_psum = time_call(f_psum, grids, reps=args.reps)
            t_slab = time_call(f_slab, grids, reps=args.reps)
            peak_psum = peak_temp_bytes(f_psum, grids)
            peak_slab = peak_temp_bytes(f_slab, grids)

            print(f"{f'd={dim} n={level}':>8} {n:>6} "
                  f"{plan.fine_size * itemsize / 2**20:>8.2f} "
                  f"{psum_dev / 2**20:>12.3f} {slab_dev / 2**20:>12.3f} "
                  f"{psum_dev / slab_dev:>8.1f}x {t_psum * 1e3:>9.2f} "
                  f"{t_slab * 1e3:>9.2f}")
            rows.append({
                "mode": "1d",
                "dim": dim, "level": level, "grids": g,
                "points": scheme_total_points(scheme),
                "fine_size": plan.fine_size, "n_groups": n,
                "slab_rows": splan.slab_rows, "slab_size": splan.slab_size,
                "dtype_bytes": itemsize,
                "psum_per_device_embedded_bytes": psum_dev,
                "sharded_per_device_embedded_bytes": slab_dev,
                "embedded_bytes_ratio": psum_dev / slab_dev,
                "ragged_slack": slack,
                "compiled_peak_temp_bytes_psum": peak_psum,
                "compiled_peak_temp_bytes_sharded": peak_slab,
                "psum_s": t_psum, "sharded_s": t_slab,
            })

    if args.mesh_2d:
        print(f"\n{'scheme':>8} {'mesh':>8} {'groups':>6} "
              f"{'dev_GFLOP':>10} {'dev_MB':>8} {'stack_MB':>9} "
              f"{'ship_MB':>8} {'t_ms':>9}")
        for dim, level in SCHEMES:
            scheme = CombinationScheme(dim, level)
            plan = build_plan(scheme)
            rng = np.random.default_rng(dim * 100 + level)
            grids = {ell: jnp.asarray(rng.standard_normal(grid_shape(ell)),
                                      DTYPE)
                     for ell, _ in scheme.grids}
            want = np.asarray(ct_transform(grids, scheme))
            for m, s in MESH2D:
                mesh = _mesh2d(m, s)
                splan = shard_plan(plan, s, n_groups=m * s)
                f_2d = jax.jit(lambda gr, ms=mesh, sp=splan:
                               ct_transform_sharded(
                                   gr, scheme, ms, "slab",
                                   member_axis="member", plan=sp))
                got = np.asarray(f_2d(grids))
                # the tentpole's acceptance bar: BIT-identical to the
                # single-device transform, not merely close
                np.testing.assert_array_equal(got, want)
                st = plan_ingest_stats(splan,
                                       dtype_bytes=np.dtype(DTYPE).itemsize)
                t_2d = time_call(f_2d, grids, reps=args.reps)
                print(f"{f'd={dim} n={level}':>8} {f'{m}x{s}':>8} "
                      f"{m * s:>6} {st['ingest_flops'] / 1e9:>10.4f} "
                      f"{st['ingest_bytes'] / 2**20:>8.3f} "
                      f"{st['stack_bytes'] / 2**20:>9.3f} "
                      f"{st['ship_bytes'] / 2**20:>8.3f} "
                      f"{t_2d * 1e3:>9.2f}")
                rows.append({
                    "mode": "2d",
                    "dim": dim, "level": level,
                    "grids": plan.num_grids,
                    "points": scheme_total_points(scheme),
                    "members": m, "slabs": s, "n_groups": m * s,
                    "dtype_bytes": np.dtype(DTYPE).itemsize,
                    "per_device_ingest_flops": st["ingest_flops"],
                    "per_device_ingest_bytes": st["ingest_bytes"],
                    "per_device_stack_bytes": st["stack_bytes"],
                    "per_device_ship_bytes": st["ship_bytes"],
                    "per_device_out_bytes": st["out_bytes"],
                    "sharded_2d_s": t_2d,
                })

    if args.json_out:
        payload = {"bench": "executor_sharded", "reps": args.reps,
                   "backend": jax.default_backend(),
                   "devices": jax.device_count(), "rows": rows}
        with open(args.json_out, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
