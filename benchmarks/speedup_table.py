"""Headline speedup table (paper Sect. 5): optimized vs Func baseline.

The paper reports 10-30x for BFS-OverVectorized vs Func and another
2-10x of Func over SGpp.  Matched sizes, wall time only."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchRow, emit_csv, time_call
from repro.core.levels import flops_eq1, flops_exact, grid_shape
from repro.kernels import ref

CASES = [(12,), (14,), (8, 8), (5, 5, 5)]


def run(reps: int = 3):
    rows = []
    opt = jax.jit(ref.hierarchize_nd_ref)
    gather = jax.jit(lambda x: _gather_nd(x))
    for lv in CASES:
        x = jnp.asarray(np.random.default_rng(sum(lv)).standard_normal(
            grid_shape(lv)))
        fe1, fex = flops_eq1(lv), flops_exact(lv)
        nbytes = x.size * x.dtype.itemsize
        t_func = time_call(lambda a: _func_nd(np.asarray(a)), x,
                           reps=1, warmup=0)
        t_opt = time_call(opt, x, reps=reps, warmup=1)
        t_gather = time_call(gather, x, reps=reps, warmup=1)
        rows.append(BenchRow("speedup", f"l={lv}", "func", nbytes, t_func,
                             fe1, fex))
        rows.append(BenchRow("speedup", f"l={lv}", "ref", nbytes, t_opt,
                             fe1, fex))
        rows.append(BenchRow("speedup", f"l={lv}", "gather", nbytes,
                             t_gather, fe1, fex))
        print(f"# {lv}: speedup ref vs func = {t_func / t_opt:7.1f}x, "
              f"gather vs func = {t_func / t_gather:7.1f}x")
    return rows


def _func_nd(x):
    for axis in range(x.ndim):
        x = ref.hierarchize_1d_bruteforce(x, axis)
    return x


def _gather_nd(x):
    for axis in range(x.ndim):
        x = ref.hierarchize_1d_gather(x, axis)
    return x


def main():
    print(emit_csv(run()))


if __name__ == "__main__":
    main()
