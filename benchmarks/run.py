"""Benchmark aggregator: one section per paper table/figure + the roofline
readers.  ``python -m benchmarks.run [--quick]``."""

from __future__ import annotations

import argparse
import sys
import time


def _section(title: str):
    print(f"\n### {title}", flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes, fewer reps")
    args = ap.parse_args(argv)

    from benchmarks import (fig4_1d_layouts, fig6_2d, fig7_4d, fig8_10d,
                            fig9_dims, kernel_roofline, speedup_table)
    from benchmarks.common import emit_csv

    t0 = time.time()
    _section("fig4_1d_layouts (paper Fig. 4)")
    rows = fig4_1d_layouts.run(levels=(10, 14, 18) if args.quick
                               else (10, 14, 18, 20, 22))
    print(emit_csv(rows))

    _section("fig6_2d measured-vs-calculated (paper Fig. 5/6)")
    rows = fig6_2d.run(level_pairs=((6, 6), (9, 9)) if args.quick else
                       ((6, 6), (8, 8), (10, 10), (11, 11), (12, 10)))
    print(emit_csv(rows))

    _section("fig7_4d (paper Fig. 7)")
    rows = fig7_4d.run(levels_list=((4, 4, 4, 4), (5, 5, 5, 5)) if args.quick
                       else ((4, 4, 4, 4), (5, 5, 5, 5), (6, 6, 6, 6),
                             (7, 6, 6, 6)))
    print(emit_csv(rows))

    _section("fig8_10d anisotropic + reduced-op ablation (paper Fig. 8)")
    rows = fig8_10d.run(l1_values=(6, 10) if args.quick else
                        (6, 8, 10, 12, 14))
    print(emit_csv(rows))

    _section("fig9_dims (paper Fig. 9)")
    print(emit_csv(fig9_dims.run()))

    _section("speedup table (paper Sect. 5 headline)")
    print(emit_csv(speedup_table.run()))

    _section("kernel roofline projection (TPU v5e)")
    kernel_roofline.main()

    _section("arch x shape roofline (from dry-run artifacts)")
    from benchmarks import roofline
    roofline.main(["--mesh", "single"])

    print(f"\n# total bench time: {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
