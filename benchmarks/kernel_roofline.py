"""TPU v5e roofline projection for the hierarchization kernels.

The transform is bandwidth-bound by construction (paper Sect. 5 reached 5%
of FLOP peak ~ its full STREAM bandwidth).  On TPU the score that matters
is the fraction of the HBM roofline each kernel schedule sustains, which
is fixed by its PASS COUNT over the data set:

  * paper-faithful pole kernel: one pass per dimension (d passes, each
    1 read + 1 write of the grid)
  * beyond-paper fused schedule: 2 passes for ANY d >= 2 (tail axes fused
    in VMEM while tiling axis 0, then axis 0 while tiling lanes), 1 pass
    for d == 1
  * matmul (MXU) variant: same traffic as its host schedule; converts the
    gather/branch structure into dense (N x N) MXU work that stays below
    the compute roof for N <= ~1900 (ridge: 2N^2B flops vs 16NB bytes).

Numbers below are derived from the kernels' BlockSpec tiling (exact HBM
traffic of the pallas_call grid) + Eq. (1)-exact flop counts; the kernels'
numerics are validated in interpret mode by tests/test_kernels_pallas.py.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.levels import (flops_exact, grid_bytes, grid_shape,
                               hierarchization_bytes, muls_reduced)
from repro.launch.analysis import TPU_V5E

__all__ = ["kernel_cases", "main"]


@dataclass
class KernelProjection:
    case: str
    method: str
    passes: float
    hbm_bytes: int
    flops: int

    @property
    def ai(self) -> float:
        return self.flops / self.hbm_bytes

    @property
    def t_mem_us(self) -> float:
        return self.hbm_bytes / TPU_V5E.hbm_bw * 1e6

    @property
    def t_compute_us(self) -> float:
        return self.flops / TPU_V5E.peak_flops * 1e6

    @property
    def bound(self) -> str:
        return "memory" if self.t_mem_us >= self.t_compute_us else "compute"

    @property
    def roofline_frac(self) -> float:
        """Fraction of the single-pass HBM roofline this schedule reaches
        (1.0 == the data set crosses HBM exactly once in + once out)."""
        return 1.0 / self.passes

    def row(self) -> str:
        return (f"kernel_roofline,{self.case},{self.method},{self.passes},"
                f"{self.hbm_bytes},{self.ai:.4f},{self.t_mem_us:.1f},"
                f"{self.t_compute_us:.2f},{self.bound},"
                f"{self.roofline_frac:.3f}")


def kernel_cases(levels_list=((20,), (10, 10), (7, 7, 6), (5, 5, 5, 5),
                              (3, 3, 3, 3, 3, 3, 2, 2, 2, 2))):
    rows = []
    for lv in levels_list:
        d = len(lv)
        case = f"l={lv}"
        gb = grid_bytes(lv)
        fl = flops_exact(lv)
        # paper-faithful: d passes (pole kernel per dimension)
        rows.append(KernelProjection(case, "pole_paper", d,
                                     hierarchization_bytes(lv), fl))
        # beyond-paper fused: 2 passes for d >= 2 (1 if d == 1)
        passes = 1 if d == 1 else 2
        # matmul variant executes 2*N flops per output elem per axis
        mm_flops = sum(2 * ((1 << li) - 1) * (gb // 8) for li in lv)
        rows.append(KernelProjection(case, "fused_mxu", passes,
                                     hierarchization_bytes(lv, passes=passes),
                                     mm_flops))
    return rows


HEADER = ("bench,case,method,passes,hbm_bytes,flops_per_byte,t_mem_us,"
          "t_compute_us,bound,frac_of_1pass_roofline")


def main():
    print(HEADER)
    for r in kernel_cases():
        print(r.row())


if __name__ == "__main__":
    main()
