"""Shared benchmark utilities.

Performance convention (paper Sect. 4): "calculated performance" divides
the THEORETICAL flop count of Eq. (1) by wall time — navigation overhead
and redundant flops then LOWER the reported number instead of inflating
it.  "measured performance" divides the flops the implementation actually
executes (flops_exact, the 2-mul unreduced form) by the same wall time —
reproducing the paper's Fig. 5/6 lesson that measured flops mislead.

The container benches run the jit-compiled JNP implementations on the CPU
(1 core); the Pallas kernels are validated in interpret mode (numerics)
and projected on the TPU roofline (benchmarks/kernel_roofline.py) — wall
time of interpret-mode emulation is meaningless and never reported.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List

import jax
import numpy as np

__all__ = ["time_call", "peak_temp_bytes", "BenchRow", "emit_csv",
           "perf_gflops"]


def peak_temp_bytes(jitted, *args):
    """Compiled peak temp allocation of a jitted callable, when the
    backend reports it.  NOTE ``lower().compile()`` goes through the AOT
    path — one extra compile per probe, independent of the jit dispatch
    cache (the price of getting ``memory_analysis`` at all)."""
    try:
        mem = jitted.lower(*args).compile().memory_analysis()
        return int(getattr(mem, "temp_size_in_bytes"))
    except Exception:
        return None


def time_call(fn: Callable, *args, reps: int = 5, warmup: int = 2,
              min_time_s: float = 0.0) -> float:
    """Median wall seconds of ``fn(*args)`` (blocks on jax outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


@dataclass
class BenchRow:
    bench: str
    case: str
    method: str
    bytes_in: int
    seconds: float
    flops_eq1: int
    flops_exact: int

    @property
    def calc_gflops(self) -> float:
        return self.flops_eq1 / self.seconds / 1e9 if self.seconds else 0.0

    @property
    def meas_gflops(self) -> float:
        return self.flops_exact / self.seconds / 1e9 if self.seconds else 0.0

    @property
    def gbps(self) -> float:
        """Effective 2x-traffic bandwidth (1 read + 1 write per pass)."""
        return 2 * self.bytes_in / self.seconds / 1e9 if self.seconds else 0.0

    def csv(self) -> str:
        return (f"{self.bench},{self.case},{self.method},{self.bytes_in},"
                f"{self.seconds * 1e6:.1f},{self.calc_gflops:.4f},"
                f"{self.meas_gflops:.4f},{self.gbps:.3f}")


CSV_HEADER = ("bench,case,method,bytes,us_per_call,calc_gflops,"
              "meas_gflops,eff_gbps")


def emit_csv(rows: Iterable[BenchRow], header: bool = True) -> str:
    lines = [CSV_HEADER] if header else []
    lines += [r.csv() for r in rows]
    return "\n".join(lines)


def perf_gflops(flops: int, seconds: float) -> float:
    return flops / seconds / 1e9 if seconds else 0.0
