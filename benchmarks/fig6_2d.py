"""Paper Fig. 5/6: 2-D grids — measured vs calculated performance.

Reproduces the paper's methodological point: dividing by the flops an
implementation EXECUTES (meas_gflops; the unreduced 2-multiply form,
flops_exact) reports higher numbers than dividing by the theoretical
Eq. (1) count (calc_gflops) for exactly the same wall time.  Only the
calculated number ranks implementations by wall time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchRow, emit_csv, time_call
from repro.core.levels import flops_eq1, flops_exact, grid_shape, num_points
from repro.kernels import ref

FUNC_MAX_POINTS = 1 << 15


def _methods():
    return {
        "func": lambda x: ref.hierarchize_1d_bruteforce(
            ref.hierarchize_1d_bruteforce(np.asarray(x), 0), 1),
        "ref": jax.jit(ref.hierarchize_nd_ref),
        "ref_unreduced": jax.jit(
            lambda x: ref.hierarchize_nd_ref(x, reduced_op=False)),
        "gather": jax.jit(lambda x: ref.hierarchize_1d_gather(
            ref.hierarchize_1d_gather(x, 0), 1)),
    }


def run(level_pairs=((6, 6), (8, 8), (10, 10), (11, 11), (12, 10)),
        reps: int = 3):
    rows = []
    methods = _methods()
    for lv in level_pairs:
        x = jnp.asarray(np.random.default_rng(sum(lv)).standard_normal(
            grid_shape(lv)))
        fe1, fex = flops_eq1(lv), flops_exact(lv)
        for name, fn in methods.items():
            if name == "func" and num_points(lv) > FUNC_MAX_POINTS:
                continue
            secs = time_call(fn, x, reps=reps, warmup=1)
            rows.append(BenchRow("fig6_2d", f"l={lv}", name,
                                 x.size * x.dtype.itemsize, secs, fe1, fex))
    return rows


def main():
    print(emit_csv(run()))


if __name__ == "__main__":
    main()
