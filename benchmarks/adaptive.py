"""Dimension-adaptive refinement benchmark: points-to-error + plan reuse.

Two measurements on the anisotropic reference targets
(``repro.configs.sparse_grid.CT_ADAPTIVE_CONFIGS``):

  * **points-to-error** — combination-grid points the regular scheme needs
    for a given max-norm interpolation error vs the dimension-adaptive
    scheme's trajectory (the headline: >= 3x fewer at the acceptance bar);
  * **plan-update cost** — wall time of the incremental ``extend_plan``
    against a from-scratch ``build_plan`` for each expansion once the fine
    grid stabilizes, plus how many buckets were reused by identity.

Emits machine-readable ``BENCH_adaptive.json`` (``--json-out`` overrides,
empty string disables).

  PYTHONPATH=src python benchmarks/adaptive.py
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.configs.sparse_grid import CT_ADAPTIVE_CONFIGS  # noqa: E402
from repro.core.adaptive import (AdaptiveConfig, AdaptiveDriver,  # noqa: E402
                                 interpolation_error,
                                 make_anisotropic_target, nodal_sampler)
from repro.core.executor import build_plan, ct_transform  # noqa: E402
from repro.core.levels import CombinationScheme  # noqa: E402


def run_case(cfg, reps: int):
    f = make_anisotropic_target(cfg.dim, cfg.decay)
    sample = nodal_sampler(f)
    pts = jnp.asarray(np.random.default_rng(cfg.eval_seed)
                      .random((cfg.eval_points, cfg.dim)))

    reg = CombinationScheme(cfg.dim, cfg.baseline_level)
    nodal = {ell: sample(ell) for ell, _ in reg.grids}
    err_reg = interpolation_error(ct_transform(nodal, reg), f, pts)

    drv = AdaptiveDriver(nodal_sampler(f), dim=cfg.dim,
                         config=AdaptiveConfig(max_points=cfg.max_points,
                                               max_level=cfg.max_level))
    traj, matched = [], None
    while True:
        err = interpolation_error(drv.surplus, f, pts)
        traj.append({"iteration": len(drv.history),
                     "points": drv.scheme.total_points(),
                     "solved_points": drv.solved_points(),
                     "grids": len(drv.scheme.grids),
                     "max_err": err})
        if matched is None and err <= err_reg:
            matched = traj[-1]
        if matched is not None or drv.step() is None:
            break

    # plan-update cost on a stable fine grid: replay the final expansion
    from repro.core.executor import clear_plan_cache, extend_plan
    plan_t = {}
    if len(drv.scheme.grids) > 1:
        prev = drv.scheme.without_levels([drv.history[-1].added[0]]) \
            if drv.history and drv.history[-1].added else None
    else:
        prev = None
    if prev is not None:
        base = build_plan(prev, full_levels=drv.plan.full_levels)
        t0 = time.perf_counter()
        for _ in range(reps):
            inc = extend_plan(base, drv.scheme,
                              full_levels=drv.plan.full_levels)
        plan_t["extend_s"] = (time.perf_counter() - t0) / reps
        t0 = time.perf_counter()
        for _ in range(reps):
            clear_plan_cache()
            scratch = build_plan(drv.scheme,
                                 full_levels=drv.plan.full_levels)
        plan_t["scratch_s"] = (time.perf_counter() - t0) / reps
        plan_t["buckets"] = len(inc.buckets)
        plan_t["buckets_reused"] = sum(
            1 for b in inc.buckets if any(b is ob for ob in base.buckets))
        assert all(np.array_equal(a.index, b.index) and
                   np.array_equal(a.coeffs, b.coeffs)
                   for a, b in zip(inc.buckets, scratch.buckets))

    return {"case": cfg.name, "dim": cfg.dim, "decay": cfg.decay,
            "regular_level": cfg.baseline_level,
            "regular_points": reg.total_points(),
            "regular_grids": len(reg.grids), "regular_max_err": err_reg,
            "trajectory": traj, "matched": matched,
            "point_ratio": (reg.total_points() / matched["points"]
                            if matched else None),
            "stop_reason": drv.stop_reason, "plan_update": plan_t}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cases", nargs="*",
                    default=["aniso_6d_smoke", "aniso_6d"])
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--json-out", default="BENCH_adaptive.json",
                    help="machine-readable results path ('' disables)")
    args = ap.parse_args(argv)

    results = []
    print(f"{'case':>16} {'reg pts':>8} {'reg err':>10} {'adapt pts':>10} "
          f"{'ratio':>7} {'extend_ms':>10} {'scratch_ms':>11} {'reused':>7}")
    for name in args.cases:
        cfg = CT_ADAPTIVE_CONFIGS[name]
        r = run_case(cfg, args.reps)
        results.append(r)
        m, p = r["matched"], r["plan_update"]
        ratio = f"{r['point_ratio']:.2f}x" if r["point_ratio"] else "-"
        print(f"{name:>16} {r['regular_points']:>8} "
              f"{r['regular_max_err']:>10.3e} "
              f"{(m['points'] if m else -1):>10} {ratio:>7} "
              f"{p.get('extend_s', 0) * 1e3:>10.3f} "
              f"{p.get('scratch_s', 0) * 1e3:>11.3f} "
              f"{p.get('buckets_reused', 0):>3}/{p.get('buckets', 0):<3}")
    if args.json_out:
        payload = {"bench": "adaptive", "backend": jax.default_backend(),
                   "results": results}
        with open(args.json_out, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
