"""§Roofline table: read the dry-run artifacts and print the three terms
per (arch x shape x mesh) cell.

  python -m benchmarks.roofline [--dir results/dryrun] [--mesh single]
  python -m benchmarks.roofline --pick   # the 3 hillclimb cells (§Perf)
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch.analysis import TPU_V5E, Roofline

HEADER = ("cell,chips,compute_s,memory_s,collective_s,bottleneck,step_s,"
          "model_flops,useful_ratio,mfu_at_roofline")


def load(dir_: str, mesh: str | None = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if not r.get("ok"):
            continue
        if mesh and r["mesh"] != mesh:
            continue
        recs.append(r)
    return recs


def to_roofline(r: dict) -> Roofline:
    return Roofline(cell=r["cell"], chips=r["chips"], hw=TPU_V5E,
                    flops_per_device=r["flops_per_device"],
                    bytes_per_device=r["bytes_per_device"],
                    collective_per_device=r["collective_bytes"],
                    model_flops_global=r["model_flops"])


def fmt(rl: Roofline) -> str:
    return (f"{rl.cell},{rl.chips},{rl.compute_s:.4e},{rl.memory_s:.4e},"
            f"{rl.collective_s:.4e},{rl.bottleneck},{rl.step_s:.4e},"
            f"{rl.model_flops_global:.3e},{rl.useful_flops_ratio:.3f},"
            f"{rl.mfu_roofline:.4f}")


def pick_hillclimb(recs):
    """The 3 §Perf cells: worst MFU-at-roofline among train cells, most
    collective-bound, and the paper-representative cell (the biggest
    all-reduce/gather consumer relative to compute = where the comm-
    preprocessing insight matters most)."""
    rls = [to_roofline(r) for r in recs]
    train = [r for r in rls if "train" in r.cell]
    worst_mfu = min(train, key=lambda r: r.mfu_roofline)
    coll = max(rls, key=lambda r: r.collective_s / max(r.step_s, 1e-30))
    ratio = lambda r: r.collective_s / max(r.compute_s, 1e-30)
    rep = max(train, key=ratio)
    picked = []
    for r in (worst_mfu, coll, rep):
        if r.cell not in [p.cell for p in picked]:
            picked.append(r)
    # backfill if dedup removed one
    for r in sorted(train, key=lambda r: r.mfu_roofline):
        if len(picked) >= 3:
            break
        if r.cell not in [p.cell for p in picked]:
            picked.append(r)
    return picked


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "all"])
    ap.add_argument("--pick", action="store_true")
    args = ap.parse_args(argv)
    mesh = None if args.mesh == "all" else args.mesh
    recs = load(args.dir, mesh)
    if not recs:
        print(f"# no dry-run artifacts in {args.dir} — run "
              f"`python -m repro.launch.dryrun --all` first")
        return
    if args.pick:
        print("# §Perf hillclimb cells "
              "(worst-MFU / most-collective-bound / paper-representative):")
        for rl in pick_hillclimb(recs):
            print(fmt(rl))
        return
    print(HEADER)
    for r in recs:
        print(fmt(to_roofline(r)))


if __name__ == "__main__":
    main()
