"""Multi-tenant CTEngine serving vs N independent surrogates.

The PR-5 claim priced here: serving N tenants whose schemes share plan
shape-signatures through ONE ``CTEngine`` compiles the jitted ingest
once per SIGNATURE (index maps and coefficients are executable
arguments), where N independent pre-engine surrogates — each a
``jax.jit`` closure with the plan baked in as constants — compile once
per TENANT.  The benchmark builds a tenant fleet with deliberate
signature sharing (M tenants per scheme, the "many surrogates of one
discretization" serving shape), measures

  * compilations + setup wall time: engine vs independent closures,
  * steady-state traffic: one continuous-batching flush (ingest overlap
    + per-signature coalesced query dispatches) vs the per-tenant
    dispatch loop, with the engine results asserted BIT-identical to the
    independent path first,
  * (PR 6) SUSTAINED QPS + tail latency under a mixed OPEN-LOOP
    ingest+query load replayed against the thread-safe engine twice at
    equal offered throughput: the deadline/priority scheduler
    (flush-on-deadline-or-batch-full, background ingest pool) vs a
    flush-everything drain loop — queries arriving during a drain's
    ingest barrier convoy behind it, which is exactly the tail the
    deadline scheduler removes,

asserts the >=2x compilation reduction AND the >=1.5x p99 win of the
deadline scheduler (the ISSUE acceptance bars), and emits
machine-readable ``BENCH_serve_engine.json`` with top-level
``qps_sustained`` / ``p99_ms`` fields.

  PYTHONPATH=src python benchmarks/serve_engine.py
"""

from __future__ import annotations

import argparse
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from common import time_call  # noqa: E402

from repro.core.engine import CTEngine, clear_compile_cache  # noqa: E402
from repro.core.executor import (build_plan, clear_plan_cache,  # noqa: E402
                                 ct_transform_with_plan)
from repro.core.interpolation import interpolate_hierarchical  # noqa: E402
from repro.core.levels import CombinationScheme, grid_shape  # noqa: E402

#: the tenant fleet: M tenants per scheme — distinct data, one signature
SCHEMES = [CombinationScheme(2, 5), CombinationScheme(3, 4),
           CombinationScheme(4, 3)]
TENANTS_PER_SCHEME = 3
QUERY_POINTS = 64


def _fleet(rng):
    tenants = []
    for scheme in SCHEMES:
        for m in range(TENANTS_PER_SCHEME):
            grids = {ell: jnp.asarray(rng.standard_normal(grid_shape(ell)))
                     for ell, _ in scheme.grids}
            tenants.append((f"d{scheme.dim}n{scheme.level}_t{m}", scheme,
                            grids))
    return tenants


def bench(reps):
    rng = np.random.default_rng(0)
    tenants = _fleet(rng)
    n = len(tenants)
    points = {name: rng.random((QUERY_POINTS, scheme.dim))
              for name, scheme, _ in tenants}

    # --- baseline: N independent pre-engine surrogates (one jit closure
    #     per tenant, plan baked in as constants) ---
    t0 = time.perf_counter()
    base_ingest, base_surplus = {}, {}
    for name, scheme, grids in tenants:
        plan = build_plan(scheme)
        fn = jax.jit(lambda g, plan=plan: ct_transform_with_plan(g, plan))
        base_surplus[name] = fn(grids)
        base_ingest[name] = fn
    base_eval = jax.jit(interpolate_hierarchical)   # shared, like the old
    base_query = {}                                 # CTSurrogate._shared_eval
    for name, scheme, _ in tenants:
        base_query[name] = np.asarray(
            base_eval(base_surplus[name], jnp.asarray(points[name])))
    jax.block_until_ready(list(base_surplus.values()))
    setup_base_s = time.perf_counter() - t0
    base_compiles = sum(f._cache_size() for f in base_ingest.values())

    # --- engine: one registry, signature-shared executables ---
    clear_compile_cache()
    t0 = time.perf_counter()
    engine = CTEngine()
    for name, scheme, grids in tenants:
        engine.register(name, scheme, grids)
    futs = {name: engine.submit_query(name, points[name])
            for name, _, _ in tenants}
    engine.flush()
    results = {name: fut.result() for name, fut in futs.items()}
    setup_engine_s = time.perf_counter() - t0
    stats = engine.stats()
    engine_compiles = stats["ingest_cache"]["jit_entries"]

    # identity against the independent path before timing anything:
    # compiled graphs are held to 1e-12 and the bitwise fraction recorded
    # (the repo-wide convention since PR 4 — XLA may FMA the scatter
    # combiner differently once index maps/coefficients are arguments
    # instead of literals; the eager/low-d paths are pinned BITWISE in
    # tests/test_engine.py)
    bitwise = 0
    for name, _, _ in tenants:
        got = np.asarray(engine.surplus(name))
        want = np.asarray(base_surplus[name])
        np.testing.assert_allclose(got, want, rtol=0, atol=1e-12)
        bitwise += int(np.array_equal(got, want))
        np.testing.assert_allclose(results[name], base_query[name],
                                   rtol=0, atol=1e-12)

    # --- steady-state traffic: re-ingest everything + answer every query
    #     (engine: one flush = N async ingests + coalesced eval batches;
    #     baseline: 2N separate dispatch round trips) ---
    def engine_round():
        for name, _, grids in tenants:
            engine.submit_ingest(name, grids)
        futs = [engine.submit_query(name, points[name])
                for name, _, _ in tenants]
        engine.flush()
        return [f.result() for f in futs]

    def baseline_round():
        out = []
        for name, _, grids in tenants:
            s = base_ingest[name](grids)
            out.append(np.asarray(
                base_eval(s, jnp.asarray(points[name]))))
        return out

    t_engine = time_call(engine_round, reps=reps, warmup=1)
    t_base = time_call(baseline_round, reps=reps, warmup=1)

    ev = engine.stats()["eval"]
    payload = {
        "bench": "serve_engine",
        "backend": jax.default_backend(),
        "tenants": n,
        "distinct_schemes": len(SCHEMES),
        "query_points_per_tenant": QUERY_POINTS,
        "compilations": {"independent": base_compiles,
                         "engine": engine_compiles,
                         "ratio": base_compiles / engine_compiles},
        "bitwise_identical_tenants": [bitwise, n],
        "setup_s": {"independent": setup_base_s, "engine": setup_engine_s},
        "round_s": {"independent": t_base, "engine": t_engine},
        "eval": {"batches_per_round": len(SCHEMES),
                 "coalesced_queries": ev["coalesced_queries"],
                 "eval_compiles": ev["compiles"]},
        "ingest_cache": stats["ingest_cache"],
    }
    print(f"{'':>24} {'independent':>12} {'engine':>12}")
    print(f"{'compilations':>24} {base_compiles:>12} {engine_compiles:>12}")
    print(f"{'setup_s':>24} {setup_base_s:>12.3f} {setup_engine_s:>12.3f}")
    print(f"{'round_s':>24} {t_base:>12.4f} {t_engine:>12.4f}")
    print(f"\n{n} tenants over {len(SCHEMES)} signatures: "
          f"{base_compiles / engine_compiles:.1f}x fewer compilations, "
          f"queries coalesced into {len(SCHEMES)} dispatches/round")

    # ISSUE acceptance: >=2x fewer compilations than N independent
    # surrogates on schemes sharing bucket signatures
    assert engine_compiles * 2 <= base_compiles, (
        f"compile dedup regressed: engine {engine_compiles} vs "
        f"independent {base_compiles}")
    return payload


# ---------------------------------------------------------------------------
# PR 6: open-loop mixed load — deadline scheduler vs flush-everything
# ---------------------------------------------------------------------------

def _schedule(n_queries, qps, ingest_every, burst):
    """Open-loop arrival schedule: queries at fixed ``qps`` spacing, a
    bulk-refresh ingest burst (``burst`` chained re-ingests of one heavy
    background tenant) every ``ingest_every`` queries — the mixed load
    that makes flush-everything convoy: its drain barriers every queued
    query behind the heavy ingest chain, while the deadline scheduler
    keeps dispatching queries on their latency budget and lets the
    ingest pool absorb the refresh."""
    events = []
    for i in range(n_queries):
        events.append((i / qps, "query", i))
        if ingest_every and i % ingest_every == ingest_every - 1:
            events.extend([(i / qps, "ingest", i)] * burst)
    return events


def _replay_open_loop(mode, events, tenants, bulk, points, deadline_ms):
    """Replay the schedule against a fresh engine in one of two drain
    modes at EQUAL offered load: ``"deadline"`` (started scheduler +
    background ingest pool) or ``"flush_everything"`` (a dedicated
    thread draining the whole queue in a loop — every cycle barriers on
    all pending ingest chains before the next starts)."""
    engine = CTEngine(deadline_ms=deadline_ms, max_pending=1_000_000)
    for name, scheme, grids in tenants:
        engine.register(name, scheme, grids)
    bulk_name, bulk_scheme, bulk_grids = bulk
    engine.register(bulk_name, bulk_scheme, bulk_grids)
    names = [name for name, _, _ in tenants]
    # warm every dispatch shape before timing: ingest executables, plus
    # the batched eval at every power-of-two T-pad bucket a deadline
    # window or a post-convoy drain can produce (group sizes vary per
    # window; the engine pads T to {4, 8, 16, 32} so only these compile)
    for name, _, grids in tenants:
        engine.submit_ingest(name, grids)
    engine.submit_ingest(bulk_name, bulk_grids)
    engine.flush()
    by_scheme = {}
    for name, scheme, _ in tenants:
        by_scheme.setdefault(scheme, name)
    for group_size in (1, 5, 9, 17):
        for scheme, name in by_scheme.items():
            for _ in range(group_size):
                engine.submit_query(name, points[name])
        engine.flush()

    stop = threading.Event()
    flusher = None
    if mode == "deadline":
        engine.start()
    else:
        def drain_loop():
            while not stop.is_set():
                engine.flush()
                time.sleep(0)           # let submitters in
        flusher = threading.Thread(target=drain_loop, daemon=True)
        flusher.start()

    qfuts, ingests = [], 0
    t0 = time.monotonic()
    for dt, kind, i in events:
        target = t0 + dt
        now = time.monotonic()
        while now < target:
            time.sleep(min(0.0005, target - now))
            now = time.monotonic()
        if kind == "query":
            name = names[i % len(names)]
            qfuts.append((time.monotonic(),
                          engine.submit_query(name, points[name])))
        else:
            engine.submit_ingest(bulk_name, bulk_grids)
            ingests += 1
    for _, f in qfuts:
        if not f._event.wait(timeout=120.0):
            raise RuntimeError(f"open-loop {mode}: query future hung")
    t_end = max(f.done_at for _, f in qfuts)

    if mode == "deadline":
        engine.close()
    else:
        stop.set()
        flusher.join(timeout=30.0)
        engine.flush()

    lat_ms = np.asarray([(f.done_at - sub) * 1e3 for sub, f in qfuts])
    sched = engine.stats()["scheduler"]
    return {
        "mode": mode,
        "queries": len(qfuts),
        "ingests": ingests,
        "qps_offered": len(qfuts) / events[-1][0],
        "qps_sustained": len(qfuts) / (t_end - t0),
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "max_ms": float(lat_ms.max()),
        "dispatch_deadline": sched["dispatch_deadline"],
        "dispatch_batch_full": sched["dispatch_batch_full"],
        "flushes": sched["flushes"],
    }


#: the heavy background tenant bulk-refreshed during the open-loop load:
#: each ingest is a few ms on CPU and a burst chains many of them, so a
#: flush-everything drain barriers queries behind the whole multi-ms
#: chain while the deadline scheduler interleaves eval dispatches
#: between the chain links
BULK_SCHEME = CombinationScheme(2, 9)


def bench_open_loop(n_queries, qps, ingest_every, burst, deadline_ms):
    rng = np.random.default_rng(1)
    tenants = _fleet(rng)
    points = {name: rng.random((QUERY_POINTS, scheme.dim))
              for name, scheme, _ in tenants}
    bulk = ("bulk_refresh",
            BULK_SCHEME,
            {ell: jnp.asarray(rng.standard_normal(grid_shape(ell)))
             for ell, _ in BULK_SCHEME.grids})
    out = {}
    for mode in ("flush_everything", "deadline"):
        out[mode] = _replay_open_loop(mode,
                                      _schedule(n_queries, qps,
                                                ingest_every, burst),
                                      tenants, bulk, points, deadline_ms)
    print(f"\n{'open-loop mixed load':>24} {'flush-all':>12} "
          f"{'deadline':>12}")
    for k in ("qps_sustained", "p50_ms", "p99_ms", "max_ms"):
        print(f"{k:>24} {out['flush_everything'][k]:>12.2f} "
              f"{out['deadline'][k]:>12.2f}")
    ratio = out["flush_everything"]["p99_ms"] / out["deadline"]["p99_ms"]
    print(f"{'p99 ratio':>24} {ratio:>25.2f}x  (bar: >=1.5x)")

    # ISSUE acceptance: the deadline scheduler beats flush-everything
    # p99 by >=1.5x at equal offered throughput
    assert ratio >= 1.5, (
        f"deadline scheduler p99 {out['deadline']['p99_ms']:.2f}ms vs "
        f"flush-everything {out['flush_everything']['p99_ms']:.2f}ms: "
        f"{ratio:.2f}x < 1.5x bar")
    return out, ratio


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--open-loop-queries", type=int, default=400)
    ap.add_argument("--open-loop-qps", type=float, default=300.0)
    ap.add_argument("--ingest-every", type=int, default=40,
                    help="one bulk-refresh ingest burst per this many "
                         "queries in the open-loop load")
    ap.add_argument("--ingest-burst", type=int, default=12,
                    help="chained re-ingests of the heavy bulk tenant "
                         "per burst")
    ap.add_argument("--deadline-ms", type=float, default=5.0)
    ap.add_argument("--json-out", default="BENCH_serve_engine.json")
    args = ap.parse_args(argv)
    payload = bench(args.reps)
    clear_compile_cache()
    clear_plan_cache()
    open_loop, ratio = bench_open_loop(args.open_loop_queries,
                                       args.open_loop_qps,
                                       args.ingest_every, args.ingest_burst,
                                       args.deadline_ms)
    payload["open_loop"] = open_loop
    payload["p99_ratio_flush_vs_deadline"] = ratio
    # the CI contract (non-null, top-level): sustained QPS + p99 of the
    # deadline-scheduled engine under the mixed open-loop load
    payload["qps_sustained"] = open_loop["deadline"]["qps_sustained"]
    payload["p99_ms"] = open_loop["deadline"]["p99_ms"]
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
