"""Multi-tenant CTEngine serving vs N independent surrogates.

The PR-5 claim priced here: serving N tenants whose schemes share plan
shape-signatures through ONE ``CTEngine`` compiles the jitted ingest
once per SIGNATURE (index maps and coefficients are executable
arguments), where N independent pre-engine surrogates — each a
``jax.jit`` closure with the plan baked in as constants — compile once
per TENANT.  The benchmark builds a tenant fleet with deliberate
signature sharing (M tenants per scheme, the "many surrogates of one
discretization" serving shape), measures

  * compilations + setup wall time: engine vs independent closures,
  * steady-state traffic: one continuous-batching flush (ingest overlap
    + per-signature coalesced query dispatches) vs the per-tenant
    dispatch loop, with the engine results asserted BIT-identical to the
    independent path first,

and asserts the >=2x compilation reduction (the ISSUE acceptance bar).
Emits machine-readable ``BENCH_serve_engine.json``.

  PYTHONPATH=src python benchmarks/serve_engine.py
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from common import time_call  # noqa: E402

from repro.core.engine import CTEngine, clear_compile_cache  # noqa: E402
from repro.core.executor import (build_plan,  # noqa: E402
                                 ct_transform_with_plan)
from repro.core.interpolation import interpolate_hierarchical  # noqa: E402
from repro.core.levels import CombinationScheme, grid_shape  # noqa: E402

#: the tenant fleet: M tenants per scheme — distinct data, one signature
SCHEMES = [CombinationScheme(2, 5), CombinationScheme(3, 4),
           CombinationScheme(4, 3)]
TENANTS_PER_SCHEME = 3
QUERY_POINTS = 64


def _fleet(rng):
    tenants = []
    for scheme in SCHEMES:
        for m in range(TENANTS_PER_SCHEME):
            grids = {ell: jnp.asarray(rng.standard_normal(grid_shape(ell)))
                     for ell, _ in scheme.grids}
            tenants.append((f"d{scheme.dim}n{scheme.level}_t{m}", scheme,
                            grids))
    return tenants


def bench(reps):
    rng = np.random.default_rng(0)
    tenants = _fleet(rng)
    n = len(tenants)
    points = {name: rng.random((QUERY_POINTS, scheme.dim))
              for name, scheme, _ in tenants}

    # --- baseline: N independent pre-engine surrogates (one jit closure
    #     per tenant, plan baked in as constants) ---
    t0 = time.perf_counter()
    base_ingest, base_surplus = {}, {}
    for name, scheme, grids in tenants:
        plan = build_plan(scheme)
        fn = jax.jit(lambda g, plan=plan: ct_transform_with_plan(g, plan))
        base_surplus[name] = fn(grids)
        base_ingest[name] = fn
    base_eval = jax.jit(interpolate_hierarchical)   # shared, like the old
    base_query = {}                                 # CTSurrogate._shared_eval
    for name, scheme, _ in tenants:
        base_query[name] = np.asarray(
            base_eval(base_surplus[name], jnp.asarray(points[name])))
    jax.block_until_ready(list(base_surplus.values()))
    setup_base_s = time.perf_counter() - t0
    base_compiles = sum(f._cache_size() for f in base_ingest.values())

    # --- engine: one registry, signature-shared executables ---
    clear_compile_cache()
    t0 = time.perf_counter()
    engine = CTEngine()
    for name, scheme, grids in tenants:
        engine.register(name, scheme, grids)
    futs = {name: engine.submit_query(name, points[name])
            for name, _, _ in tenants}
    engine.flush()
    results = {name: fut.result() for name, fut in futs.items()}
    setup_engine_s = time.perf_counter() - t0
    stats = engine.stats()
    engine_compiles = stats["ingest_cache"]["jit_entries"]

    # identity against the independent path before timing anything:
    # compiled graphs are held to 1e-12 and the bitwise fraction recorded
    # (the repo-wide convention since PR 4 — XLA may FMA the scatter
    # combiner differently once index maps/coefficients are arguments
    # instead of literals; the eager/low-d paths are pinned BITWISE in
    # tests/test_engine.py)
    bitwise = 0
    for name, _, _ in tenants:
        got = np.asarray(engine.surplus(name))
        want = np.asarray(base_surplus[name])
        np.testing.assert_allclose(got, want, rtol=0, atol=1e-12)
        bitwise += int(np.array_equal(got, want))
        np.testing.assert_allclose(results[name], base_query[name],
                                   rtol=0, atol=1e-12)

    # --- steady-state traffic: re-ingest everything + answer every query
    #     (engine: one flush = N async ingests + coalesced eval batches;
    #     baseline: 2N separate dispatch round trips) ---
    def engine_round():
        for name, _, grids in tenants:
            engine.submit_ingest(name, grids)
        futs = [engine.submit_query(name, points[name])
                for name, _, _ in tenants]
        engine.flush()
        return [f.result() for f in futs]

    def baseline_round():
        out = []
        for name, _, grids in tenants:
            s = base_ingest[name](grids)
            out.append(np.asarray(
                base_eval(s, jnp.asarray(points[name]))))
        return out

    t_engine = time_call(engine_round, reps=reps, warmup=1)
    t_base = time_call(baseline_round, reps=reps, warmup=1)

    ev = engine.stats()["eval"]
    payload = {
        "bench": "serve_engine",
        "backend": jax.default_backend(),
        "tenants": n,
        "distinct_schemes": len(SCHEMES),
        "query_points_per_tenant": QUERY_POINTS,
        "compilations": {"independent": base_compiles,
                         "engine": engine_compiles,
                         "ratio": base_compiles / engine_compiles},
        "bitwise_identical_tenants": [bitwise, n],
        "setup_s": {"independent": setup_base_s, "engine": setup_engine_s},
        "round_s": {"independent": t_base, "engine": t_engine},
        "eval": {"batches_per_round": len(SCHEMES),
                 "coalesced_queries": ev["coalesced_queries"],
                 "eval_compiles": ev["compiles"]},
        "ingest_cache": stats["ingest_cache"],
    }
    print(f"{'':>24} {'independent':>12} {'engine':>12}")
    print(f"{'compilations':>24} {base_compiles:>12} {engine_compiles:>12}")
    print(f"{'setup_s':>24} {setup_base_s:>12.3f} {setup_engine_s:>12.3f}")
    print(f"{'round_s':>24} {t_base:>12.4f} {t_engine:>12.4f}")
    print(f"\n{n} tenants over {len(SCHEMES)} signatures: "
          f"{base_compiles / engine_compiles:.1f}x fewer compilations, "
          f"queries coalesced into {len(SCHEMES)} dispatches/round")

    # ISSUE acceptance: >=2x fewer compilations than N independent
    # surrogates on schemes sharing bucket signatures
    assert engine_compiles * 2 <= base_compiles, (
        f"compile dedup regressed: engine {engine_compiles} vs "
        f"independent {base_compiles}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--json-out", default="BENCH_serve_engine.json")
    args = ap.parse_args(argv)
    payload = bench(args.reps)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
