"""Paper Fig. 4: hierarchizing a 1-D grid — data layout / navigation study.

Methods (paper name -> this repo):
  SGpp/Func -> ``func``   numpy node-by-node with level-index navigation
  Ind       -> ``ref``    jit'd strided level loop, no level-index vector
  (one-shot)-> ``gather`` jit'd linear-operator gather
  BFS       -> ``bfs``    jit'd level-major layout
  BFS-Rev   -> ``bfs_rev``

The paper's observations to reproduce: Func is slowest (navigation
overhead); Ind wins at moderate sizes; BFS performance stays flat as data
grows; Reverse-BFS is slower than BFS.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchRow, emit_csv, time_call
from repro.core.hierarchize import hierarchize_1d_bfs, to_bfs
from repro.core.levels import flops_eq1, flops_exact
from repro.kernels import ref

FUNC_MAX_LEVEL = 15     # python-loop baseline; larger sizes time out


def _methods():
    h_ref = jax.jit(lambda x: ref.hierarchize_1d_ref(x, axis=0))
    h_gather = jax.jit(lambda x: ref.hierarchize_1d_gather(x, axis=0))
    h_bfs = jax.jit(functools.partial(hierarchize_1d_bfs, axis=0))
    h_bfs_rev = jax.jit(functools.partial(hierarchize_1d_bfs, axis=0,
                                          reverse=True))
    return {
        "func": lambda x: ref.hierarchize_1d_bruteforce(np.asarray(x), 0),
        "ref": h_ref,
        "gather": h_gather,
        "bfs": h_bfs,
        "bfs_rev": h_bfs_rev,
    }


def run(levels=(10, 14, 18, 20, 22), reps: int = 3):
    rows = []
    methods = _methods()
    for level in levels:
        n = (1 << level) - 1
        x = jnp.asarray(np.random.default_rng(level).standard_normal(n))
        xb = to_bfs(x, 0)
        fe1, fex = flops_eq1((level,)), flops_exact((level,))
        for name, fn in methods.items():
            if name == "func" and level > FUNC_MAX_LEVEL:
                continue
            arg = xb if name.startswith("bfs") else x
            secs = time_call(fn, arg, reps=reps, warmup=1)
            rows.append(BenchRow("fig4_1d", f"l={level}", name,
                                 n * x.dtype.itemsize, secs, fe1, fex))
    return rows


def main():
    print(emit_csv(run()))


if __name__ == "__main__":
    main()
