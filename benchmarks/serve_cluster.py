"""CTCluster serving under a mid-run host kill + restart: the failover
and durability SLO bench.

The PR-7 claim priced here: a 4-host `CTCluster` absorbs the loss of a
host in the middle of an open-loop serving load with ZERO dropped
futures — every request submitted before, during, and after the kill
resolves to a value or to the named ``HostFailed`` (unreplicated
in-flight ingests only; queries are transparently retried on the new
owner) — and the post-recovery tail stays within 3x of the pre-failover
tail at equal offered load.

The PR-9 claim stacked on top: with per-host durable stores (WAL +
surplus snapshots) the victim is RESTARTED mid-load — fresh engine over
the same store, restore -> rejoin -> WAL replay — after which placement
returns EXACTLY to the pre-kill assignment and every tenant's answers
are BIT-IDENTICAL to a never-crashed single-engine oracle fed the same
acked ingests (``lost_acked_ingests == 0``, the chaos CI bar).  The
recovery time is split into its three phases (snapshot restore, ring
re-placement, WAL replay).

The harness replays ``benchmarks/serve_engine.py``'s open-loop schedule
(fixed-QPS queries + periodic ingest bursts) against the cluster front
door, kills the primary of a live tenant at the half-way mark via the
``FaultInjector``, lets the health monitor (heartbeat + probe query)
detect and fail it over, then calls ``restart_host`` at the 3/4 mark
WITHOUT pausing the load, and records

  * ``recovery_ms`` — injected kill to failover complete (victim out of
    the ring, every tenant re-owned): detection latency + migration,
  * ``restart`` — the restore / replace (re-placement) / replay split
    of the rejoin, in ms,
  * ``dropped_futures`` — hung (never resolved) or resolved with an
    UNNAMED error; the CI bar is exactly 0,
  * ``lost_acked_ingests`` — tenants whose post-restart answers differ
    from the oracle fed their newest acked payload; the CI bar is 0,
  * ``p99_pre_ms`` / ``p99_post_ms`` — query tail latency for arrivals
    before the kill vs after the restart completed, same offered QPS.

  PYTHONPATH=src python benchmarks/serve_cluster.py
"""

from __future__ import annotations

import argparse
import json
import tempfile
import threading
import time

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core.engine import CTEngine, EngineSaturated  # noqa: E402
from repro.core.levels import CombinationScheme, grid_shape  # noqa: E402
from repro.runtime.cluster import CTCluster, HostFailed  # noqa: E402
from repro.runtime.fault_tolerance import HostHealthConfig  # noqa: E402

#: tenant fleet: M tenants per scheme — deliberate signature sharing, so
#: migrated tenants re-bind from the process-global executable cache
#: (failover compiles nothing)
SCHEMES = [CombinationScheme(2, 5), CombinationScheme(3, 4),
           CombinationScheme(4, 3)]
TENANTS_PER_SCHEME = 3
QUERY_POINTS = 64
N_HOSTS = 4

#: errors that count as RESOLVED, not dropped: the named failover error
#: plus the engine's own per-request validation/NaN errors
NAMED_ERRORS = (HostFailed, EngineSaturated, FloatingPointError, KeyError,
                ValueError)


def _fleet(rng):
    tenants = []
    for scheme in SCHEMES:
        for m in range(TENANTS_PER_SCHEME):
            grids = {ell: rng.standard_normal(grid_shape(ell))
                     for ell, _ in scheme.grids}
            tenants.append((f"d{scheme.dim}n{scheme.level}_t{m}", scheme,
                            grids))
    return tenants


def _schedule(n_queries, qps, ingest_every, burst):
    """Open-loop arrivals: queries at fixed ``qps`` spacing, a burst of
    ``burst`` ingests every ``ingest_every`` queries."""
    events = []
    for i in range(n_queries):
        events.append((i / qps, "query", i))
        if ingest_every and i % ingest_every == ingest_every - 1:
            events.extend([(i / qps, "ingest", i + j) for j in range(burst)])
    return events


def _warmup(cluster, tenants, points):
    """Compile every dispatch shape before timing: each signature's
    ingest executable (registration did that) plus the batched eval at
    the power-of-two T-pad buckets the per-host scheduler can form."""
    for group_size in (1, 5, 9, 17):
        futs = []
        for name, _, _ in tenants:
            futs.extend(cluster.submit_query(name, points[name])
                        for _ in range(group_size))
        for f in futs:
            f.result(120.0)


def _oracle_mismatches(tenants, points, initial, ingest_log, got):
    """Never-crashed oracle: one fresh engine per tenant fed the same
    acked ingests (full-dict last-writer-wins -> the newest acked
    payload IS the final state).  Returns the tenants whose cluster
    answers are not bit-identical to the oracle's."""
    bad = []
    for name, scheme, _ in tenants:
        acked = [(seq, payload) for seq, payload, ok in ingest_log[name]
                 if ok]
        final = max(acked, key=lambda x: x[0])[1] if acked \
            else initial[name]
        oracle = CTEngine(host_id="oracle")
        oracle.register(name, scheme, final)
        want = oracle.query(name, points[name])
        if not np.array_equal(np.asarray(got[name]), np.asarray(want)):
            bad.append(name)
    return bad


def bench(n_queries, qps, ingest_every, burst, deadline_ms,
          durability_dir=None):
    rng = np.random.default_rng(0)
    tenants = _fleet(rng)
    names = [name for name, _, _ in tenants]
    points = {name: rng.random((QUERY_POINTS, scheme.dim))
              for name, scheme, _ in tenants}
    initial = {name: grids for name, _, grids in tenants}
    base_refresh = {name: {ell: rng.standard_normal(grid_shape(ell))
                           for ell, _ in scheme.grids}
                    for name, scheme, _ in tenants}

    durability_dir = durability_dir or tempfile.mkdtemp(
        prefix="ct-durability-")
    cluster = CTCluster(
        N_HOSTS, replication=1, seed=7,
        health=HostHealthConfig(heartbeat_timeout_s=1.0,
                                probe_deadline_s=0.5, max_strikes=2),
        monitor_interval_s=0.05,
        durability_dir=durability_dir, snapshot_interval=8,
        engine_kwargs={"deadline_ms": deadline_ms,
                       "max_pending": 1_000_000})
    for name, scheme, grids in tenants:
        cluster.register(name, scheme, grids)
    placement = {n: list(cluster.owners_of(n)) for n in names}

    events = _schedule(n_queries, qps, ingest_every, burst)
    kill_at = events[len(events) // 2][0]      # half-way arrival time
    restart_at = events[(3 * len(events)) // 4][0]
    victim = cluster.owners_of(names[0])[0]
    victim_tenants = [n for n in names if cluster.owners_of(n)[0] == victim]

    #: per-tenant ingest payload log: (cluster submit order, payload,
    #: acked) — distinct payloads per submission so the oracle check is
    #: sensitive to a LOST acked ingest, not just a lost tenant
    ingest_log = {n: [] for n in names}
    ingest_counter = {n: 0 for n in names}

    with cluster:                              # start hosts + monitor
        _warmup(cluster, tenants, points)

        def _recovered():
            return victim not in cluster.live_hosts() and all(
                victim not in cluster.owners_of(n) for n in names)

        restart_result = {}

        def _do_restart():
            t = time.monotonic()
            restart_result["outcomes"] = cluster.restart_host(victim)
            restart_result["wall_ms"] = (time.monotonic() - t) * 1e3

        futs, killed_t, recovered_t = [], None, None
        restart_thread = None
        t0 = time.monotonic()
        for dt, kind, i in events:
            target = t0 + dt
            now = time.monotonic()
            while now < target:
                time.sleep(min(0.0005, target - now))
                now = time.monotonic()
            if killed_t is None and now - t0 >= kill_at:
                cluster.injector.kill(victim)  # mid-run host loss
                killed_t = time.monotonic()
            if killed_t is not None and recovered_t is None \
                    and _recovered():
                recovered_t = time.monotonic()
            if restart_thread is None and now - t0 >= restart_at \
                    and recovered_t is not None:
                # rejoin the victim at full load: restore + re-place +
                # WAL replay race the open-loop arrivals below
                restart_thread = threading.Thread(target=_do_restart,
                                                  daemon=True)
                restart_thread.start()
            name = names[i % len(names)]
            sub = time.monotonic()
            if kind == "query":
                futs.append((sub, "query", None,
                             cluster.submit_query(name, points[name])))
            else:
                k = ingest_counter[name] = ingest_counter[name] + 1
                payload = {ell: g * (1.0 + 0.01 * k)
                           for ell, g in base_refresh[name].items()}
                f = cluster.submit_ingest(name, payload)
                ingest_log[name].append([k, payload, f])
                futs.append((sub, "ingest", name, f))
        if killed_t is None:                   # load ended early: kill now
            cluster.injector.kill(victim)
            killed_t = time.monotonic()

        # failover complete = victim out of the ring and un-owned
        deadline = time.monotonic() + 60.0
        while recovered_t is None and time.monotonic() < deadline:
            if _recovered():
                recovered_t = time.monotonic()
                break
            time.sleep(0.001)
        assert recovered_t is not None, "failover never completed"
        recovery_ms = (recovered_t - killed_t) * 1e3

        # the restart must run even if the schedule ended before 3/4
        if restart_thread is None:
            restart_thread = threading.Thread(target=_do_restart,
                                              daemon=True)
            restart_thread.start()
        restart_thread.join(timeout=120.0)
        assert not restart_thread.is_alive(), "restart_host hung"
        restart_done_t = time.monotonic()

        # a post-restart tail at the same offered spacing, so the
        # recovered steady state has its own latency samples
        tail = max(50, len(events) // 4)
        for i in range(tail):
            target = restart_done_t + i / qps
            now = time.monotonic()
            while now < target:
                time.sleep(min(0.0005, target - now))
                now = time.monotonic()
            name = names[i % len(names)]
            futs.append((time.monotonic(), "query", None,
                         cluster.submit_query(name, points[name])))

        hung = unnamed = host_failed = retried = 0
        q_lat = []                             # (submit_t, latency_ms)
        for sub, kind, _, f in futs:
            if not f.wait(120.0):
                hung += 1
                continue
            err = f.error()
            if err is not None:
                if isinstance(err, HostFailed):
                    host_failed += 1
                elif not isinstance(err, NAMED_ERRORS):
                    unnamed += 1
                continue
            retried += f.retargeted
            if kind == "query":
                q_lat.append((sub, (f.done_at - sub) * 1e3))
        dropped = hung + unnamed
        # resolve the ingest log to (seq, payload, acked) triples
        for n in names:
            ingest_log[n] = [(k, payload,
                              f.done() and f.error() is None)
                             for k, payload, f in ingest_log[n]]

        pre = np.asarray([ms for sub, ms in q_lat if sub < killed_t])
        post = np.asarray([ms for sub, ms in q_lat
                           if sub > restart_done_t])
        stats = cluster.stats()

        # post-restart: placement returned to the PRE-KILL assignment
        # (same seeded vnodes), and every tenant answers
        placement_after = {n: list(cluster.owners_of(n)) for n in names}
        got = {n: cluster.query(n, points[n]) for n in names}
        for n in names:
            assert np.all(np.isfinite(got[n]))

    lost = _oracle_mismatches(tenants, points, initial, ingest_log, got)

    p99_pre = float(np.percentile(pre, 99)) if len(pre) else None
    p99_post = float(np.percentile(post, 99)) if len(post) else None
    failover = stats["failovers"][0] if stats["failovers"] else {}
    restart = stats["restarts"][-1] if stats["restarts"] else {}

    payload = {
        "bench": "serve_cluster",
        "backend": jax.default_backend(),
        "hosts": N_HOSTS,
        "tenants": len(tenants),
        "distinct_schemes": len(SCHEMES),
        "replication": 1,
        "qps_offered": qps,
        "queries": int(sum(1 for _, k, _, _ in futs if k == "query")),
        "ingests": int(sum(1 for _, k, _, _ in futs if k == "ingest")),
        "placement": placement,
        "victim": victim,
        "victim_tenants": victim_tenants,
        # --- the CI contract (top-level, non-null) ---
        "recovery_ms": recovery_ms,
        "dropped_futures": dropped,
        "lost_acked_ingests": len(lost),
        "p99_pre_ms": p99_pre,
        "p99_post_ms": p99_post,
        # --- durability / restart detail ---
        "durability_dir": durability_dir,
        "restart": {
            "outcomes": restart.get("outcomes", {}),
            "restore_ms": restart.get("restore_ms"),
            "replace_ms": restart.get("replace_ms"),
            "replay_ms": restart.get("replay_ms"),
            "total_ms": restart.get("total_ms"),
            "replayed_entries": restart.get("replayed"),
            "wall_ms": restart_result.get("wall_ms"),
        },
        "placement_restored": placement_after == placement,
        "lost_tenants": lost,
        # --- failover detail ---
        "hung_futures": hung,
        "unnamed_errors": unnamed,
        "host_failed_resolutions": host_failed,
        "transparent_retries": retried,
        "migration_ms": failover.get("recovery_ms"),
        "failover_outcomes": failover.get("outcomes", {}),
        "failover_log": stats["failovers"],
        "restart_log": stats["restarts"],
        "retried_queries": stats["retried_queries"],
        "promoted_ingests": stats["promoted_ingests"],
        "replayed_ingests": stats["replayed_ingests"],
        "p50_pre_ms": float(np.percentile(pre, 50)) if len(pre) else None,
        "p50_post_ms": float(np.percentile(post, 50)) if len(post) else None,
        "pre_samples": int(len(pre)),
        "post_samples": int(len(post)),
    }

    print(f"{'':>26} {'pre-failover':>14} {'post-restart':>14}")
    print(f"{'query p50 (ms)':>26} {payload['p50_pre_ms']:>14.2f} "
          f"{payload['p50_post_ms']:>14.2f}")
    print(f"{'query p99 (ms)':>26} {p99_pre:>14.2f} {p99_post:>14.2f}")
    print(f"\nkilled {victim} (primary of {len(victim_tenants)} tenants) "
          f"mid-replay: failed over in {recovery_ms:.1f} ms "
          f"(migration {failover.get('recovery_ms', 0):.1f} ms), "
          f"{stats['retried_queries']} queries retried transparently, "
          f"{host_failed} ingests resolved HostFailed, "
          f"{stats['replayed_ingests']} replayed from the WAL, "
          f"{dropped} dropped futures")
    print(f"restarted {victim} mid-load: restore "
          f"{restart.get('restore_ms', 0):.1f} ms + re-place "
          f"{restart.get('replace_ms', 0):.1f} ms + WAL replay "
          f"{restart.get('replay_ms', 0):.1f} ms "
          f"({restart.get('replayed', 0)} entries); placement restored: "
          f"{payload['placement_restored']}; lost acked ingests: "
          f"{len(lost)}")

    # --- acceptance bars (also asserted from CI on the JSON) ---
    assert dropped == 0, (
        f"{hung} hung + {unnamed} unnamed-error futures: the failover "
        f"path dropped requests")
    assert recovery_ms is not None and recovery_ms > 0
    assert not lost, (
        f"tenants {lost} diverged from the never-crashed oracle: acked "
        f"ingests were lost across the kill/restart")
    assert payload["placement_restored"], (
        "restart did not return placement to the pre-kill assignment")
    # equal offered load before/after: the tail may grow briefly but the
    # recovered steady state stays within 3x + a small CPU-noise floor
    assert p99_pre is not None and p99_post is not None
    assert p99_post <= 3.0 * p99_pre + 5.0, (
        f"post-restart p99 {p99_post:.2f}ms vs pre {p99_pre:.2f}ms: "
        f"exceeds the 3x bar")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--queries", type=int, default=400)
    ap.add_argument("--qps", type=float, default=150.0)
    ap.add_argument("--ingest-every", type=int, default=50,
                    help="one ingest burst per this many queries")
    ap.add_argument("--ingest-burst", type=int, default=3,
                    help="tenant refresh ingests per burst")
    ap.add_argument("--deadline-ms", type=float, default=5.0)
    ap.add_argument("--durability-dir", default=None,
                    help="durable store root (default: fresh temp dir)")
    ap.add_argument("--json-out", default="BENCH_serve_cluster.json")
    args = ap.parse_args(argv)
    payload = bench(args.queries, args.qps, args.ingest_every,
                    args.ingest_burst, args.deadline_ms,
                    durability_dir=args.durability_dir)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
