"""Paper Fig. 9: the best implementation across dimensions 1..5 at roughly
matched data-set sizes — performance should be similar for d in 2..5 and
lower for d=1 (fewer poles to batch over)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchRow, emit_csv, time_call
from repro.core.levels import flops_eq1, flops_exact, grid_shape
from repro.kernels import ref

# ~matched sizes (2^20-ish points)
CASES = {
    1: (20,),
    2: (10, 10),
    3: (7, 7, 6),
    4: (5, 5, 5, 5),
    5: (4, 4, 4, 4, 4),
}


def run(reps: int = 3):
    rows = []
    best = jax.jit(ref.hierarchize_nd_ref)
    for d, lv in CASES.items():
        x = jnp.asarray(np.random.default_rng(d).standard_normal(
            grid_shape(lv)))
        secs = time_call(best, x, reps=reps, warmup=1)
        rows.append(BenchRow("fig9_dims", f"d={d}", "ref",
                             x.size * x.dtype.itemsize, secs,
                             flops_eq1(lv), flops_exact(lv)))
    return rows


def main():
    print(emit_csv(run()))


if __name__ == "__main__":
    main()
