"""Paper Fig. 7: hierarchizing 4-D grids (isotropic sweep).

Adds the fused 2-round-trip schedule (beyond-paper) against the d-pass
reference: on a bandwidth-bound transform the pass count is the first-order
cost, visible even on the CPU container.
"""

from __future__ import annotations

import jax
import numpy as np
import jax.numpy as jnp

from benchmarks.common import BenchRow, emit_csv, time_call
from repro.core.levels import flops_eq1, flops_exact, grid_shape
from repro.kernels import ref
from repro.kernels.hierarchize import hierarchize_nd_fused


def _fused_jnp(x):
    """The fused schedule expressed in pure jnp (tensordot per tail axis on
    a VMEM-sized block is emulated by whole-array tensordots on CPU)."""
    d = x.ndim
    for axis in range(1, d):
        h = jnp.asarray(ref.operator_matrix(int(np.log2(x.shape[axis] + 1))),
                        x.dtype)
        x = jnp.moveaxis(jnp.tensordot(h, x, axes=[[1], [axis]]), 0, axis)
    h0 = jnp.asarray(ref.operator_matrix(int(np.log2(x.shape[0] + 1))),
                     x.dtype)
    return jnp.tensordot(h0, x, axes=[[1], [0]])


def run(levels_list=((4, 4, 4, 4), (5, 5, 5, 5), (6, 6, 6, 6),
                     (7, 6, 6, 6)), reps: int = 3):
    rows = []
    methods = {
        "ref": jax.jit(ref.hierarchize_nd_ref),
        "gather": jax.jit(lambda x: _gather_nd(x)),
        "fused_matmul": jax.jit(_fused_jnp),
    }
    for lv in levels_list:
        x = jnp.asarray(np.random.default_rng(sum(lv)).standard_normal(
            grid_shape(lv)))
        fe1, fex = flops_eq1(lv), flops_exact(lv)
        for name, fn in methods.items():
            secs = time_call(fn, x, reps=reps, warmup=1)
            rows.append(BenchRow("fig7_4d", f"l={lv}", name,
                                 x.size * x.dtype.itemsize, secs, fe1, fex))
    return rows


def _gather_nd(x):
    for axis in range(x.ndim):
        x = ref.hierarchize_1d_gather(x, axis)
    return x


def main():
    print(emit_csv(run()))


if __name__ == "__main__":
    main()
