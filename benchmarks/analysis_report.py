"""BENCH_analysis.json: static invariant findings + runtime lockdep
coverage in one artifact.

Runs the `repro.analysis` static pass over the whole package, then an
instrumented 4-thread engine workload with the runtime sanitizer
forced on, and emits the combined machine-readable report CI uploads
and gates on (``violations == 0`` and zero runtime cycles).

  PYTHONPATH=src python benchmarks/analysis_report.py
"""

from __future__ import annotations

import argparse
import json
import threading
import time

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.analysis import lockdep  # noqa: E402
from repro.analysis import locklint, report  # noqa: E402
from repro.core.engine import CTEngine  # noqa: E402
from repro.core.levels import CombinationScheme, grid_shape  # noqa: E402


def _lockdep_workload() -> dict:
    """4 threads x 4 tenants of instrumented engine traffic; returns
    the sanitizer's graph summary."""
    lockdep.enable()
    lockdep.reset()
    t0 = time.perf_counter()
    try:
        scheme = CombinationScheme(2, 3)
        eng = CTEngine()
        names = [f"t{i}" for i in range(4)]
        for i, name in enumerate(names):
            rng = np.random.default_rng(i)
            eng.register(name, scheme,
                         {ell: rng.standard_normal(grid_shape(ell))
                          for ell, _ in scheme.grids})
        eng.start()

        def work(name, i):
            rng = np.random.default_rng(100 + i)
            for _ in range(3):
                grids = {ell: rng.standard_normal(grid_shape(ell))
                         for ell, _ in scheme.grids}
                eng.submit_ingest(name, grids).result(30)
                eng.submit_query(
                    name, rng.random((16, 2))).result(30)

        threads = [threading.Thread(target=work, args=(n, i))
                   for i, n in enumerate(names)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60)
        eng.stop()
        rep = lockdep.report()
        return {
            "workload": "4-thread engine ingest+query",
            "wall_s": round(time.perf_counter() - t0, 3),
            "edges": rep["edges"],
            "cycles": len(rep["cycles"]),
            "order_violations": len(rep["order_violations"]),
            "dispatch_under_lock": len(rep["dispatch_under_lock"]),
        }
    finally:
        lockdep.reset()
        lockdep.restore_default()


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--json", default="BENCH_analysis.json")
    args = parser.parse_args()

    findings, files = locklint.lint_paths()
    dep = _lockdep_workload()
    payload = report.build_report(findings, files, lockdep_report=dep)
    report.write_json(payload, args.json)
    print(json.dumps({k: payload[k] for k in
                      ("violations", "files_scanned", "per_rule")},
                     indent=2))
    print("lockdep:", json.dumps(dep))
    if payload["violations"] or dep["cycles"] \
            or dep["order_violations"] or dep["dispatch_under_lock"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
