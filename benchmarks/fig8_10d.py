"""Paper Fig. 8: 10-dimensional anisotropic grids.

First dimension refined (l1 sweep), the other nine fixed at level ~1.6
(paper: 3 points per axis -> level 2 every other axis to keep sizes sane:
we use (l1, 2, 2, 2, 1, 2, 1, 2, 1, 2) ~ the paper's 3-point axes).
Includes the reduced-op ablation (paper: no runtime change)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchRow, emit_csv, time_call
from repro.core.levels import flops_eq1, flops_exact, grid_shape
from repro.kernels import ref

TAIL = (2, 2, 2, 1, 2, 1, 2, 1, 2)   # nine more dims, 3 or 1 points each


def run(l1_values=(6, 8, 10, 12, 14), reps: int = 3):
    rows = []
    methods = {
        "ref": jax.jit(ref.hierarchize_nd_ref),
        "ref_unreduced": jax.jit(
            lambda x: ref.hierarchize_nd_ref(x, reduced_op=False)),
        "gather": jax.jit(lambda x: _gather_nd(x)),
    }
    for l1 in l1_values:
        lv = (l1,) + TAIL
        x = jnp.asarray(np.random.default_rng(l1).standard_normal(
            grid_shape(lv)))
        fe1, fex = flops_eq1(lv), flops_exact(lv)
        for name, fn in methods.items():
            secs = time_call(fn, x, reps=reps, warmup=1)
            rows.append(BenchRow("fig8_10d", f"l1={l1}", name,
                                 x.size * x.dtype.itemsize, secs, fe1, fex))
    return rows


def _gather_nd(x):
    for axis in range(x.ndim):
        x = ref.hierarchize_1d_gather(x, axis)
    return x


def main():
    print(emit_csv(run()))


if __name__ == "__main__":
    main()
