"""Dict-loop vs batched-executor CT communication phase.

The repo's first multi-grid throughput number: for each scheme, time

  * ``dict``    — the oracle path: one ``hierarchize(..., "ref")`` dispatch
    per component grid + ``combine_full``'s per-grid embed loop, the whole
    thing wrapped in ONE jit (so the comparison is dispatch structure, not
    python overhead);
  * ``batched`` — ``repro.core.executor.ct_transform``: bucket-batched
    hierarchization + static-index-plan scatter-add, also one jit.

Both paths produce the sparse-grid surplus on the common fine grid; the
benchmark asserts they agree to 1e-12 before timing.

Emits machine-readable ``BENCH_executor_batched.json`` next to the table
(``--json-out`` overrides, empty string disables) so the perf trajectory
is tracked across PRs.

  PYTHONPATH=src python benchmarks/executor_batched.py
"""

from __future__ import annotations

import argparse
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from common import time_call  # noqa: E402

from repro.core import combination as comb  # noqa: E402
from repro.core.executor import build_plan, ct_transform  # noqa: E402
from repro.core.levels import CombinationScheme, grid_shape  # noqa: E402
from repro.kernels.ops import hierarchize  # noqa: E402

# (dim, sparse-grid level): d=10 stays at level 2 — the common fine grid
# at (d=10, n=3) is 7^10 = 282M points, beyond any embedded representation
SCHEMES = [(2, 5), (2, 7), (4, 4), (4, 5), (10, 2)]


def dict_path(scheme):
    def run(nodal_grids):
        hier = {ell: hierarchize(u, "ref") for ell, u in nodal_grids.items()}
        full, _ = comb.combine_full(hier, scheme)
        return full
    return jax.jit(run)


def batched_path(scheme):
    return jax.jit(functools.partial(ct_transform, scheme=scheme))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--json-out", default="BENCH_executor_batched.json",
                    help="machine-readable results path ('' disables)")
    args = ap.parse_args(argv)

    rows = []
    print(f"{'scheme':>10} {'grids':>6} {'buckets':>8} {'points':>10} "
          f"{'dict_ms':>9} {'batched_ms':>11} {'speedup':>8}")
    for dim, level in SCHEMES:
        scheme = CombinationScheme(dim, level)
        plan = build_plan(scheme)
        rng = np.random.default_rng(dim * 100 + level)
        grids = {ell: jnp.asarray(rng.standard_normal(grid_shape(ell)))
                 for ell, _ in scheme.grids}

        f_dict = dict_path(scheme)
        f_batched = batched_path(scheme)
        err = float(jnp.max(jnp.abs(f_dict(grids) - f_batched(grids))))
        assert err < 1e-12, (dim, level, err)

        t_dict = time_call(f_dict, grids, reps=args.reps)
        t_batched = time_call(f_batched, grids, reps=args.reps)
        print(f"{f'd={dim} n={level}':>10} {plan.num_grids:>6} "
              f"{len(plan.buckets):>8} {scheme.total_points():>10} "
              f"{t_dict * 1e3:>9.2f} {t_batched * 1e3:>11.2f} "
              f"{t_dict / t_batched:>7.2f}x")
        rows.append({"dim": dim, "level": level, "grids": plan.num_grids,
                     "buckets": len(plan.buckets),
                     "points": scheme.total_points(),
                     "max_abs_err": err, "dict_s": t_dict,
                     "batched_s": t_batched,
                     "speedup": t_dict / t_batched})
    if args.json_out:
        payload = {"bench": "executor_batched", "reps": args.reps,
                   "backend": jax.default_backend(), "rows": rows}
        with open(args.json_out, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
