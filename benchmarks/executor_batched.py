"""Dict-loop vs batched-executor CT communication phase, plus the
bucket-merge / fused-epilogue accounting.

The repo's first multi-grid throughput number: for each scheme, time

  * ``dict``    — the oracle path: one ``hierarchize(..., "ref")`` dispatch
    per component grid + ``combine_full``'s per-grid embed loop, the whole
    thing wrapped in ONE jit (so the comparison is dispatch structure, not
    python overhead);
  * ``batched`` — ``repro.core.executor.ct_transform``: bucket-batched
    hierarchization + static-index-plan scatter-add, also one jit.

Both paths produce the sparse-grid surplus on the common fine grid; the
benchmark asserts they agree to 1e-12 before timing.

The second table prices the PR-4 levers on the batched path itself:

  * merged vs unmerged — ``build_plan(..., merge=MergeConfig())``:
    launch counts (plan-derived AND the dispatches actually traced,
    ``repro.kernels.hierarchize.count_launches``) with the cost-model
    partition against the exact-canonical one;
  * fused vs unfused — the scatter-add epilogue: plan-derived
    gather-phase HBM bytes (the compact-surplus round trip the fused
    kernels eliminate) and, when XLA reports it, the compiled peak temp
    bytes (``memory_analysis``).  NOTE on the CPU container the Pallas
    kernels run in interpret mode, so the compiled peak includes the
    emulation's staging buffers and CPU wall time prices dispatches at
    CPU (not TPU) cost — the plan-derived bytes/launches are the tracked
    metrics, the TPU run is the ROADMAP "TPU validation" item;
  * every variant is asserted against the unmerged unfused path before
    timing (eager execution is bit-identical — pinned by
    ``tests/test_merge_plan.py``; compiled graphs are held to 1e-12 since
    XLA may FMA a scatter combiner, and the observed bitwise fraction is
    recorded).

Emits machine-readable ``BENCH_executor_batched.json`` and
``BENCH_bucket_merge.json`` next to the tables (``--json-out`` /
``--merge-json-out`` override, empty string disables) so the perf
trajectory is tracked across PRs.

  PYTHONPATH=src python benchmarks/executor_batched.py
"""

from __future__ import annotations

import argparse
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from common import peak_temp_bytes, time_call  # noqa: E402

from repro.core import combination as comb  # noqa: E402
from repro.core.executor import (MergeConfig, build_plan,  # noqa: E402
                                 ct_transform, ct_transform_with_plan,
                                 plan_launch_stats)
from repro.core.levels import (CombinationScheme, GeneralScheme,  # noqa: E402
                               grid_shape)
from repro.kernels.hierarchize import count_launches  # noqa: E402
from repro.kernels.ops import hierarchize  # noqa: E402

# (dim, sparse-grid level): d=10 stays at level 2 — the common fine grid
# at (d=10, n=3) is 7^10 = 282M points, beyond any embedded representation
SCHEMES = [(2, 5), (2, 7), (4, 4), (4, 5), (10, 2)]

# merge/fuse table: the d=10 wide diagonal is the launch-bound shape the
# merge planner exists for; the near-square d=2 set keeps every bucket on
# the Pallas path, so the fused epilogue engages end to end
MERGE_SCHEMES = [
    ("d=10 n=2", CombinationScheme(10, 2)),
    ("d=4 n=4", CombinationScheme(4, 4)),
    ("d=2 n=7", CombinationScheme(2, 7)),
    ("sq d=2", GeneralScheme.from_levels([(8, 6), (7, 7), (6, 8)],
                                         close=True)),
]


def dict_path(scheme):
    def run(nodal_grids):
        hier = {ell: hierarchize(u, "ref") for ell, u in nodal_grids.items()}
        full, _ = comb.combine_full(hier, scheme)
        return full
    return jax.jit(run)


def batched_path(scheme):
    return jax.jit(functools.partial(ct_transform, scheme=scheme))


def _traced_launches(plan, grids):
    """Kernel dispatches one compiled gather will issue: counted while
    tracing (pallas_call launches + jnp-path stacked-operator dispatches
    + the plan's standalone XLA scatters)."""
    with count_launches() as counts:
        jax.jit(lambda g: ct_transform_with_plan(g, plan)).lower(grids)
    return (counts["pallas"] + counts["einsum"]
            + plan_launch_stats(plan)["scatter_dispatches"])


def bench_merge(reps, json_out):
    rows = []
    print(f"\n{'scheme':>8} {'grids':>6} {'buckets':>8} {'launches':>13} "
          f"{'stack_KB':>13} {'peak_MB':>13} {'base_ms':>8} {'merged_ms':>10}")
    for case_i, (name, scheme) in enumerate(MERGE_SCHEMES):
        plain = build_plan(scheme)
        merged = build_plan(scheme, merge=MergeConfig())
        rng = np.random.default_rng(1000 + case_i)
        grids = {ell: jnp.asarray(rng.standard_normal(grid_shape(ell)))
                 for ell, _ in scheme.grids}

        f_base = jax.jit(lambda g: ct_transform_with_plan(g, plain,
                                                          fused=False))
        f_fused = jax.jit(lambda g: ct_transform_with_plan(g, plain))
        f_merged = jax.jit(lambda g: ct_transform_with_plan(g, merged))
        # eager results are bit-identical across all variants (pinned by
        # tests/test_merge_plan.py); under jit XLA may fuse a scatter
        # combiner (observed: one FMA'd slot, 1 ulp), so the compiled
        # paths are held to 1e-12 and the bitwise fraction is recorded
        want = np.asarray(f_base(grids))
        got_fused = np.asarray(f_fused(grids))
        got_merged = np.asarray(f_merged(grids))
        np.testing.assert_allclose(got_fused, want, rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(got_merged, want, rtol=1e-12, atol=1e-12)
        err = max(float(np.max(np.abs(got_fused - want))),
                  float(np.max(np.abs(got_merged - want))))
        bitwise = bool((got_fused == want).all() and
                       (got_merged == want).all())

        s_plain = plan_launch_stats(plain)
        s_merged = plan_launch_stats(merged)
        s_plain_unf = plan_launch_stats(plain, fused=False)
        s_merged_unf = plan_launch_stats(merged, fused=False)
        traced_plain = _traced_launches(plain, grids)
        traced_merged = _traced_launches(merged, grids)
        t_base = time_call(f_base, grids, reps=reps)
        t_fused = time_call(f_fused, grids, reps=reps)
        t_merged = time_call(f_merged, grids, reps=reps)
        peak_unf = peak_temp_bytes(f_base, grids)
        peak_fused = peak_temp_bytes(f_fused, grids)
        peak_merged = peak_temp_bytes(f_merged, grids)

        fmt_peak = (f"{(peak_unf or 0) / 2**20:>6.2f}"
                    f"->{(peak_merged or 0) / 2**20:<6.2f}"
                    if peak_unf is not None else f"{'n/a':>13}")
        print(f"{name:>8} {plain.num_grids:>6} "
              f"{len(plain.buckets):>3}->{len(merged.buckets):<4} "
              f"{s_plain['launches']:>6}->{s_merged['launches']:<6} "
              f"{s_plain_unf['stack_bytes'] / 1024:>6.1f}"
              f"->{s_plain['stack_bytes'] / 1024:<6.1f} "
              f"{fmt_peak} {t_base * 1e3:>8.2f} {t_merged * 1e3:>10.2f}")
        rows.append({
            "scheme": name, "grids": plain.num_grids,
            "buckets_unmerged": len(plain.buckets),
            "buckets_merged": len(merged.buckets),
            "launches_unmerged": s_plain["launches"],
            "launches_merged": s_merged["launches"],
            "launches_traced_unmerged": traced_plain,
            "launches_traced_merged": traced_merged,
            "launch_ratio": s_plain["launches"] / s_merged["launches"],
            "stack_bytes_unfused": s_plain_unf["stack_bytes"],
            "stack_bytes_fused": s_plain["stack_bytes"],
            "stack_bytes_merged_unfused": s_merged_unf["stack_bytes"],
            "stack_bytes_merged_fused": s_merged["stack_bytes"],
            "transform_bytes_unmerged": s_plain["transform_bytes"],
            "transform_bytes_merged": s_merged["transform_bytes"],
            "compiled_peak_temp_bytes_unfused": peak_unf,
            "compiled_peak_temp_bytes_fused": peak_fused,
            "compiled_peak_temp_bytes_merged": peak_merged,
            "unmerged_unfused_s": t_base, "unmerged_fused_s": t_fused,
            "merged_fused_s": t_merged, "max_abs_err": err,
            "bitwise_equal_compiled": bitwise,
        })
    wide = next(r for r in rows if r["scheme"] == "d=10 n=2")
    assert wide["launches_unmerged"] >= 2 * wide["launches_merged"], wide
    sq = next(r for r in rows if r["scheme"] == "sq d=2")
    assert sq["stack_bytes_fused"] == 0 < sq["stack_bytes_unfused"], sq
    if json_out:
        payload = {"bench": "bucket_merge", "reps": reps,
                   "backend": jax.default_backend(), "rows": rows}
        with open(json_out, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"wrote {json_out}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--json-out", default="BENCH_executor_batched.json",
                    help="machine-readable results path ('' disables)")
    ap.add_argument("--merge-json-out", default="BENCH_bucket_merge.json",
                    help="bucket-merge results path ('' disables)")
    ap.add_argument("--skip-dict", action="store_true",
                    help="only run the merge/fuse table")
    args = ap.parse_args(argv)
    if args.skip_dict:
        bench_merge(args.reps, args.merge_json_out)
        return

    rows = []
    print(f"{'scheme':>10} {'grids':>6} {'buckets':>8} {'points':>10} "
          f"{'dict_ms':>9} {'batched_ms':>11} {'speedup':>8}")
    for dim, level in SCHEMES:
        scheme = CombinationScheme(dim, level)
        plan = build_plan(scheme)
        rng = np.random.default_rng(dim * 100 + level)
        grids = {ell: jnp.asarray(rng.standard_normal(grid_shape(ell)))
                 for ell, _ in scheme.grids}

        f_dict = dict_path(scheme)
        f_batched = batched_path(scheme)
        err = float(jnp.max(jnp.abs(f_dict(grids) - f_batched(grids))))
        assert err < 1e-12, (dim, level, err)

        t_dict = time_call(f_dict, grids, reps=args.reps)
        t_batched = time_call(f_batched, grids, reps=args.reps)
        print(f"{f'd={dim} n={level}':>10} {plan.num_grids:>6} "
              f"{len(plan.buckets):>8} {scheme.total_points():>10} "
              f"{t_dict * 1e3:>9.2f} {t_batched * 1e3:>11.2f} "
              f"{t_dict / t_batched:>7.2f}x")
        rows.append({"dim": dim, "level": level, "grids": plan.num_grids,
                     "buckets": len(plan.buckets),
                     "points": scheme.total_points(),
                     "max_abs_err": err, "dict_s": t_dict,
                     "batched_s": t_batched,
                     "speedup": t_dict / t_batched})
    if args.json_out:
        payload = {"bench": "executor_batched", "reps": args.reps,
                   "backend": jax.default_backend(), "rows": rows}
        with open(args.json_out, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"wrote {args.json_out}")
    bench_merge(args.reps, args.merge_json_out)


if __name__ == "__main__":
    main()
