"""Quickstart: the paper's pipeline behind the unified front door.

1. Build the combination scheme for a 2-D sparse grid.
2. Sample functions on every combination grid (the "solver" output).
3. ``ExecSpec`` — ONE config object for the whole execution stack —
   drives the batched gather (``ct_transform``): hierarchize every grid
   in bucket-batched Pallas kernels + one static-index scatter-add.
4. ``CTEngine`` — serve SEVERAL surrogates multi-tenant: equal plan
   shape-signatures share one compiled ingest executable, and queries
   submitted together coalesce into one batched eval dispatch.
5. Scatter back (``ct_scatter``) for the iterated-CT round trip.
6. The pre-ExecSpec keywords still work as deprecation shims (warn once).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import warnings

import jax.numpy as jnp
import numpy as np

from repro.core.engine import CTEngine, ExecSpec
from repro.core.executor import ct_scatter, ct_transform
from repro.core.interpolation import sample_function
from repro.core.levels import CombinationScheme, grid_shape


def f(x, y):
    return jnp.sin(jnp.pi * x) * y * (1 - y)


def g(x, y):
    return x * (1 - x) * jnp.sin(jnp.pi * y)


def main():
    scheme = CombinationScheme(dim=2, level=5)
    print(f"sparse grid level {scheme.level}: {len(scheme.grids)} combination "
          f"grids, {scheme.total_points()} grid points total "
          f"(vs {(2 ** 5 - 1) ** 2} for the full grid)")

    # --- compute phase (black-box solver; here: sampling f and g) ---
    nodal_f = {ell: sample_function(f, ell) for ell, _ in scheme.grids}
    nodal_g = {ell: sample_function(g, ell) for ell, _ in scheme.grids}

    # --- one ExecSpec drives every execution knob (all defaults here:
    #     no merging, single device, auto-fused epilogue, backend-default
    #     interpret mode) ---
    spec = ExecSpec()
    full = ct_transform(nodal_f, scheme, spec=spec)
    print(f"combined surplus buffer: {full.shape}")

    # --- multi-tenant serving: two surrogates, ONE compiled ingest ---
    engine = CTEngine(spec=spec)
    engine.register("f", scheme, nodal_f)
    engine.register("g", scheme, nodal_g)   # same shape-signature: cache hit
    cache = engine.stats()["ingest_cache"]
    print(f"ingest executables: {cache['misses']} compiled, "
          f"{cache['hits']} shared (2 tenants)")
    assert cache["misses"] == 1 and cache["hits"] == 1

    # --- continuous batching: both queries in ONE batched dispatch ---
    pts = np.random.default_rng(0).random((512, 2))
    fut_f = engine.submit_query("f", pts)
    fut_g = engine.submit_query("g", pts)
    engine.flush()
    err_f = float(np.max(np.abs(fut_f.result()
                                - np.asarray(f(pts[:, 0], pts[:, 1])))))
    err_g = float(np.max(np.abs(fut_g.result()
                                - np.asarray(g(pts[:, 0], pts[:, 1])))))
    ev = engine.stats()["eval"]
    print(f"max interpolation error at 512 random points: "
          f"f {err_f:.2e}, g {err_g:.2e} "
          f"({ev['queries']} queries in {ev['batches']} batched dispatch)")
    assert err_f < 5e-3 and err_g < 5e-3 and ev["batches"] == 1

    # --- scatter back (iterated-CT round trip): the combined interpolant
    #     reproduces consistent component-grid values at their own nodes ---
    back = ct_scatter(engine.surplus("f"), scheme, spec=spec)
    drift = max(float(jnp.max(jnp.abs(back[ell] - nodal_f[ell])))
                for ell, _ in scheme.grids)
    print(f"round-trip drift on consistent grids: {drift:.2e}")

    # --- the legacy kwargs still work (deprecation shims, warn once) ---
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        legacy = ct_transform(nodal_f, scheme, interpret=None,
                              merge=None)        # defaults: no warning
        assert not caught
        from repro.core.executor import MergeConfig
        legacy = ct_transform(nodal_f, scheme, merge=MergeConfig())
    assert np.array_equal(np.asarray(legacy), np.asarray(full))
    print(f"legacy merge= kwarg: same result bit-for-bit, "
          f"{len(caught)} DeprecationWarning (then silent)")
    print("OK")


if __name__ == "__main__":
    main()
