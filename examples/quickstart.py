"""Quickstart: the paper's pipeline in 60 lines.

1. Build the combination scheme for a 2-D sparse grid.
2. Sample a function on every combination grid (the "solver" output).
3. Hierarchize each grid (the paper's kernel — here the fused Pallas path,
   interpret-mode on CPU).
4. Communication phase: gather the sparse-grid surpluses, scatter back.
5. Evaluate the combined interpolant and compare against the function.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import combination as comb
from repro.core.hierarchize import dehierarchize, hierarchize
from repro.core.interpolation import interpolate_hierarchical, sample_function
from repro.core.levels import CombinationScheme, grid_shape


def f(x, y):
    return jnp.sin(jnp.pi * x) * y * (1 - y)


def main():
    scheme = CombinationScheme(dim=2, level=5)
    print(f"sparse grid level {scheme.level}: {len(scheme.grids)} combination "
          f"grids, {scheme.total_points()} grid points total "
          f"(vs {(2 ** 5 - 1) ** 2} for the full grid)")

    # --- compute phase (black-box solver; here: sampling f) ---
    nodal = {ell: sample_function(f, ell) for ell, _ in scheme.grids}

    # --- hierarchize (the paper's kernel) ---
    hier = {ell: hierarchize(u, method="fused") for ell, u in nodal.items()}

    # --- communication phase: ONE dense buffer, no interpolation needed ---
    full, full_levels = comb.combine_full(hier, scheme)
    print(f"combined surplus buffer: {grid_shape(full_levels)}")

    # --- evaluate the sparse-grid interpolant ---
    pts = jnp.asarray(np.random.default_rng(0).random((512, 2)))
    approx = interpolate_hierarchical(full, pts)
    exact = f(pts[:, 0], pts[:, 1])
    err = float(jnp.max(jnp.abs(approx - exact)))
    print(f"max interpolation error at 512 random points: {err:.2e}")
    assert err < 5e-3

    # --- scatter back + dehierarchize (iterated CT round-trip) ---
    scattered = comb.scatter_subspaces(
        comb.gather_subspaces(hier, scheme), scheme)
    back = {ell: dehierarchize(a, method="fused")
            for ell, a in scattered.items()}
    drift = max(float(jnp.max(jnp.abs(back[ell] - nodal[ell])))
                for ell, _ in scheme.grids)
    print(f"round-trip drift on consistent grids: {drift:.2e}")
    print("OK")


if __name__ == "__main__":
    main()
