"""Batched LM serving example: prefill a batch of prompts, decode new
tokens greedily against the KV/state cache.

Works for any assigned arch (reduced config on CPU):
  PYTHONPATH=src python examples/serve_lm.py --arch zamba2_1_2b
"""

import argparse

import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.launch.serve import ServeConfig, generate


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm_360m", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    out = generate(ServeConfig(arch=args.arch,
                               max_new_tokens=args.max_new_tokens,
                               temperature=args.temperature), prompts)
    for i in range(args.batch):
        new = out["tokens"][i, args.prompt_len:]
        print(f"req {i}: prompt={prompts[i].tolist()[:6]}... "
              f"generated={new.tolist()}  "
              f"mean_logprob={out['logprobs'][i].mean():.3f}")
    print("OK")


if __name__ == "__main__":
    main()
