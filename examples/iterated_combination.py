"""The iterated combination technique (paper Fig. 2) on the heat equation.

Every round: t solver steps on each combination grid -> hierarchize ->
gather -> scatter -> dehierarchize.  Prints the max error of the combined
solution against the exact separable solution after every round.

Run:  PYTHONPATH=src python examples/iterated_combination.py [--dim 2]
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core.iterated import IteratedCombination
from repro.core.levels import CombinationScheme
from repro.core.pde import heat_exact_factor, heat_init, heat_run, stable_dt


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dim", type=int, default=2)
    ap.add_argument("--level", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--t-steps", type=int, default=8)
    ap.add_argument("--nu", type=float, default=0.05)
    ap.add_argument("--hier-method", default="auto",
                    choices=["auto", "ref", "fused", "matmul", "gather"])
    args = ap.parse_args(argv)

    scheme = CombinationScheme(args.dim, args.level)
    dt = min(stable_dt(ell, args.nu) for ell, _ in scheme.grids)
    print(f"dim={args.dim} level={args.level}: {len(scheme.grids)} grids, "
          f"dt={dt:.3e}")

    it = IteratedCombination(
        scheme,
        lambda ell, u, steps: heat_run(u, steps, nu=args.nu, dt=dt),
        hier_method=args.hier_method)
    it.init(heat_init)

    pts = jnp.asarray(np.random.default_rng(0).random((256, args.dim))
                      * 0.8 + 0.1)
    exact0 = np.prod(np.sin(np.pi * np.asarray(pts)), axis=1)
    t = 0.0
    for r in range(1, args.rounds + 1):
        it.round(args.t_steps)
        t += args.t_steps * dt
        exact = heat_exact_factor(args.dim, args.nu, t) * exact0
        approx = np.asarray(it.evaluate(pts))
        err = np.max(np.abs(approx - exact))
        print(f"round {r}: physical t={t:.4f}  max|err|={err:.3e}")
    print("OK")


if __name__ == "__main__":
    main()
