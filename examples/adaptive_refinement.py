"""Dimension-adaptive refinement: same error, >= 3x fewer points.

The ISSUE's acceptance demo: on an anisotropic d=6 target (per-axis
importance falling off like 4**-i, adapted to the repo's zero-boundary
basis — see ``repro.core.adaptive.make_anisotropic_target``), the
surplus-driven dimension-adaptive scheme reaches the REGULAR level-4
scheme's max-norm interpolation error with >= 3x fewer combination-grid
points.  Along the way every expansion updates the executor plan
incrementally (``extend_plan``): once the fine grid stabilizes, untouched
buckets are reused by object identity.

The execution policy rides in ONE ``ExecSpec`` (the PR-5 front door):
the same spec drives the regular baseline transform, the adaptive
driver's incremental plans, and — at the end — a multi-tenant
``CTEngine`` serving the adaptively refined scheme NEXT TO the regular
one (Jakeman & Roberts' many-schemes-side-by-side serving shape), where
queries submitted together coalesce into batched dispatches.

Run:  PYTHONPATH=src python examples/adaptive_refinement.py
"""

import jax
import numpy as np
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.configs.sparse_grid import get_ct_adaptive_config  # noqa: E402
from repro.core.adaptive import (AdaptiveConfig, AdaptiveDriver,  # noqa: E402
                                 interpolation_error,
                                 make_anisotropic_target, nodal_sampler)
from repro.core.engine import CTEngine, ExecSpec  # noqa: E402
from repro.core.executor import ct_transform  # noqa: E402
from repro.core.levels import CombinationScheme  # noqa: E402


def main():
    cfg = get_ct_adaptive_config("aniso_6d")
    f = make_anisotropic_target(cfg.dim, cfg.decay)
    sample = nodal_sampler(f)
    pts = jnp.asarray(np.random.default_rng(cfg.eval_seed)
                      .random((cfg.eval_points, cfg.dim)))
    spec = ExecSpec()                 # one config for the whole pipeline

    # --- baseline: the regular scheme at the acceptance level ---
    reg = CombinationScheme(cfg.dim, cfg.baseline_level)
    nodal = {ell: sample(ell) for ell, _ in reg.grids}
    err_reg = interpolation_error(ct_transform(nodal, reg, spec=spec),
                                  f, pts)
    print(f"regular  d={cfg.dim} n={cfg.baseline_level}: "
          f"{len(reg.grids)} grids, {reg.total_points()} points, "
          f"max err {err_reg:.3e}")

    # --- dimension-adaptive refinement until it matches that error ---
    drv = AdaptiveDriver(nodal_sampler(f), dim=cfg.dim,
                         config=AdaptiveConfig(max_points=cfg.max_points,
                                               max_level=cfg.max_level),
                         spec=spec)
    print(f"{'iter':>4} {'refined':>20} {'grids':>6} {'points':>7} "
          f"{'reused':>9} {'max err':>10}")
    while True:
        err = interpolation_error(drv.surplus, f, pts)
        it = len(drv.history)
        refined = drv.history[-1].refined if drv.history else "(initial)"
        reuse = (f"{drv.history[-1].buckets_reused}/"
                 f"{drv.history[-1].buckets}" if drv.history else "-")
        print(f"{it:>4} {str(refined):>20} {len(drv.scheme.grids):>6} "
              f"{drv.scheme.total_points():>7} {reuse:>9} {err:>10.3e}")
        if err <= err_reg:
            break
        if drv.step() is None:
            raise SystemExit(f"stopped ({drv.stop_reason}) before reaching "
                             f"the regular scheme's error")

    pts_adapt = drv.scheme.total_points()
    ratio = reg.total_points() / pts_adapt
    print(f"\nadaptive matches the regular scheme's error with "
          f"{pts_adapt} combination-grid points vs {reg.total_points()} "
          f"-> {ratio:.2f}x fewer")
    incr = [r for r in drv.history if not r.full_rebuild]
    print(f"plan updates: {len(drv.history)} expansions, "
          f"{len(incr)} incremental (buckets reused by identity), "
          f"{len(drv.history) - len(incr)} full rebuilds (fine grid grew)")
    assert ratio >= 3.0, ratio

    # --- serve BOTH schemes side by side through the engine front door:
    #     the refined surrogate answers next to the regular baseline, and
    #     queries submitted together coalesce per plan signature ---
    engine = CTEngine(spec=spec)
    engine.register("regular", reg, nodal)
    engine.register("adaptive", drv.scheme, drv.nodal_grids)
    q = np.asarray(pts[:128])
    futs = {name: engine.submit_query(name, q)
            for name in ("regular", "adaptive")}
    engine.flush()
    exact = np.asarray(f(*[q[:, j] for j in range(cfg.dim)]))
    stats = engine.stats()
    for name, fut in futs.items():
        err = float(np.max(np.abs(fut.result() - exact)))
        print(f"engine tenant {name!r:>10}: max err {err:.3e}")
        assert err <= 2 * err_reg
    print(f"multi-scheme serving: {stats['eval']['queries']} queries in "
          f"{stats['eval']['batches']} batched dispatch(es), "
          f"{stats['ingest_cache']['misses']} ingest executable(s) compiled")
    print("OK")


if __name__ == "__main__":
    main()
