"""End-to-end LM training driver (deliverable (b): ~100M model, a few
hundred steps) using the full substrate: deterministic data pipeline,
AdamW + cosine schedule, health monitor, atomic checkpoints.

Presets:
  --preset 100m   ~100M-param smollm-family model (the deliverable run;
                  several hours on this 1-core CPU container, realtime on
                  any accelerator)
  --preset 20m    ~20M params — demonstrates the same run in minutes
  --preset smoke  seconds, CI-scale

Run:  PYTHONPATH=src python examples/train_lm.py --preset 20m --steps 200
"""

import argparse

from repro.configs import get_config
from repro.launch.train import TrainConfig, train
from repro.models.config import ModelConfig


def preset_config(name: str) -> ModelConfig:
    base = get_config("smollm_360m")
    if name == "100m":
        # smollm-family, ~100M params (vocab padded): 12L x 768
        return base.replace(num_layers=12, d_model=768, num_heads=12,
                            num_kv_heads=4, head_dim=64, d_ff=2048,
                            vocab_size=32000, dtype="float32", remat=False)
    if name == "20m":
        return base.replace(num_layers=8, d_model=384, num_heads=6,
                            num_kv_heads=2, head_dim=64, d_ff=1024,
                            vocab_size=8192, dtype="float32", remat=False)
    if name == "smoke":
        return base.replace(num_layers=2, d_model=64, num_heads=4,
                            num_kv_heads=2, head_dim=16, d_ff=128,
                            vocab_size=512, dtype="float32", remat=False)
    raise ValueError(name)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="20m",
                    choices=["100m", "20m", "smoke"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="results/train_lm_ckpt")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args(argv)

    cfg = preset_config(args.preset)
    print(f"preset={args.preset}: {cfg.param_count() / 1e6:.1f}M params")

    import repro.launch.train as T

    orig_smoke = T.get_smoke_config
    T.get_smoke_config = lambda arch: cfg    # inject the preset config
    try:
        tc = TrainConfig(arch="smollm_360m", smoke=True, steps=args.steps,
                         seq_len=args.seq_len,
                         global_batch=args.global_batch,
                         peak_lr=args.lr, warmup_steps=max(10, args.steps // 10),
                         checkpoint_dir=args.ckpt_dir,
                         checkpoint_every=max(20, args.steps // 5),
                         log_every=10)
        res = train(tc)
    finally:
        T.get_smoke_config = orig_smoke

    steps = sorted(res.losses)
    k = max(1, len(steps) // 10)
    first = sum(res.losses[s] for s in steps[:k]) / k
    last = sum(res.losses[s] for s in steps[-k:]) / k
    for s in steps[:: max(1, len(steps) // 20)]:
        print(f"step {s:5d}  loss {res.losses[s]:.4f}")
    print(f"\nfirst-{k} mean loss {first:.4f} -> last-{k} mean loss "
          f"{last:.4f}  (rollbacks: {res.rollbacks})")
    assert last < first, "loss did not improve"
    print("OK")


if __name__ == "__main__":
    main()
